//! Failure injection: the coordinator must fail loudly and cleanly —
//! no hangs, no silent corruption — when the substrate misbehaves.

use std::path::PathBuf;

use theano_mgpu::comm::collective::build_fabric;
use theano_mgpu::comm::GradExchanger;
use theano_mgpu::config::{ClusterConfig, DataConfig, TrainConfig, TransportKind};
use theano_mgpu::coordinator::trainer::train;
use theano_mgpu::data::loader::{BatchSource, LoaderCfg, ParallelLoader};
use theano_mgpu::data::shard::ShardedDataset;
use theano_mgpu::data::synth::{generate_dataset, SynthSpec};
use theano_mgpu::error::Error;

fn fresh_dataset(tag: &str, classes: usize) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tmg_fail_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = SynthSpec { classes, hw: 36, seed: 13, ..Default::default() };
    generate_dataset(&dir, &spec, 256, 32, 128).unwrap();
    dir
}

fn cfg_for(dir: PathBuf, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = "alexnet-micro".into();
    cfg.backend = "native".into();
    cfg.batch_per_worker = 8;
    cfg.steps = steps;
    cfg.log_every = 0;
    cfg.cluster = ClusterConfig::single();
    cfg.data = DataConfig {
        dir,
        train_examples: 256,
        val_examples: 32,
        shard_examples: 128,
        seed: 13,
        stored_hw: 36,
    };
    cfg
}

#[test]
fn corrupt_shard_detected_at_open() {
    let dir = fresh_dataset("crc", 10);
    // Corrupt a payload byte of the first train shard.
    let shard = dir.join("train_0000.shard");
    let mut bytes = std::fs::read(&shard).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&shard, &bytes).unwrap();
    match ShardedDataset::open(&dir, "train", true) {
        Err(err) => assert!(matches!(err, Error::Shard { .. }), "{err}"),
        Ok(_) => panic!("corrupt shard must be rejected"),
    }
}

#[test]
fn missing_mean_image_is_a_clean_error() {
    let dir = fresh_dataset("mean", 10);
    std::fs::remove_file(dir.join("mean.f32")).unwrap();
    let lcfg = LoaderCfg {
        data_dir: &dir,
        split: "train",
        batch: 8,
        crop_hw: 32,
        worker: 0,
        workers: 1,
        seed: 1,
        train_augment: true,
        verify_shards: false,
    };
    assert!(ParallelLoader::new(&lcfg).is_err());
}

#[test]
fn oversized_crop_rejected() {
    let dir = fresh_dataset("crop", 10);
    let lcfg = LoaderCfg {
        data_dir: &dir,
        split: "train",
        batch: 8,
        crop_hw: 99, // stored images are 36px
        worker: 0,
        workers: 1,
        seed: 1,
        train_augment: true,
        verify_shards: false,
    };
    match ParallelLoader::new(&lcfg) {
        Err(err) => assert!(matches!(err, Error::Shape(_)), "{err}"),
        Ok(_) => panic!("oversized crop must be rejected"),
    }
}

#[test]
fn class_count_mismatch_rejected_before_training() {
    // 50-class corpus against the 10-class micro model: out-of-range
    // labels would corrupt the loss inside the step (any backend); the
    // guard must catch it first.
    let dir = fresh_dataset("classes", 50);
    let cfg = cfg_for(dir, 2);
    let err = train(&cfg).unwrap_err();
    assert!(format!("{err}").contains("classes"), "{err}");
}

#[test]
fn unavailable_artifact_backend_falls_back_to_native() {
    // An artifact tag with no artifacts on disk must not dead-end: the
    // backend factory warns and trains on the native CPU path instead.
    let dir = fresh_dataset("artifact", 10);
    let mut cfg = cfg_for(dir, 2);
    cfg.backend = "warp9000".into();
    cfg.artifacts_dir = std::path::Path::new("/nonexistent/artifacts").to_path_buf();
    let s = train(&cfg).unwrap();
    assert_eq!(s.steps, 2);
}

#[test]
fn unknown_model_is_a_clean_error() {
    // No architecture and no manifest: nothing can compute a step, and
    // the error says so instead of hanging a worker.
    let dir = fresh_dataset("nomodel", 10);
    let mut cfg = cfg_for(dir, 2);
    cfg.model = "resnet".into();
    cfg.artifacts_dir = std::path::Path::new("/nonexistent/artifacts").to_path_buf();
    assert!(train(&cfg).is_err());
}

#[test]
fn loader_drop_mid_stream_does_not_hang() {
    let dir = fresh_dataset("drop", 10);
    let lcfg = LoaderCfg {
        data_dir: &dir,
        split: "train",
        batch: 8,
        crop_hw: 32,
        worker: 0,
        workers: 1,
        seed: 1,
        train_augment: true,
        verify_shards: false,
    };
    let mut loader = ParallelLoader::new(&lcfg).unwrap();
    let _ = loader.next_batch().unwrap();
    // Drop while the producer is mid-prefetch; Drop impl must join.
    drop(loader);
}

#[test]
fn mismatched_bucket_layout_is_a_protocol_error_not_a_hang() {
    // Ranks disagreeing on bucket_elems (config drift) must surface as
    // a per-bucket protocol error at the join barrier, never a
    // deadlock.  Total 20: both layouts share the final bucket
    // [16, 20), so the first reduction succeeds; the next round trips
    // an 8-element bucket against a 16-element one and the exact
    // shape/sequence check fires on both sides.
    let fabrics = build_fabric(2, &[TransportKind::HostStaged]);
    let joins: Vec<_> = fabrics
        .into_iter()
        .enumerate()
        .map(|(rank, fabric)| {
            std::thread::spawn(move || {
                let bucket_elems = if rank == 0 { 8 } else { 16 };
                let mut ex = GradExchanger::new(fabric, 20, bucket_elems, false);
                ex.grad_ready(0, &[1.0; 20]).unwrap();
                ex.join().map(|g| g.to_vec())
            })
        })
        .collect();
    for j in joins {
        let res = j.join().unwrap();
        assert!(matches!(res, Err(Error::Protocol(_))), "want protocol error, got {res:?}");
    }
}

#[test]
fn dead_peer_mid_bucket_round_is_an_error_not_a_hang() {
    // One rank's fabric hangs up before exchanging anything; the
    // survivor's join barrier must report the broken link instead of
    // blocking forever on a bucket that will never arrive.
    let mut fabrics = build_fabric(2, &[TransportKind::HostStaged]);
    let survivor = fabrics.remove(0);
    let dead = fabrics.remove(0);
    let t = std::thread::spawn(move || {
        let mut ex = GradExchanger::new(survivor, 12, 4, false);
        ex.grad_ready(0, &[1.0; 12]).unwrap();
        ex.join().map(|g| g.to_vec())
    });
    drop(dead); // the peer endpoint hangs up
    let res = t.join().unwrap();
    assert!(matches!(res, Err(Error::Protocol(_))), "want protocol error, got {res:?}");
}

#[test]
fn stalled_peer_with_io_deadline_is_a_timeout_not_a_hang() {
    // The peer stays *alive* but never participates (a wedged process,
    // not a dead one — drop-based tests can't catch this case): with
    // an I/O deadline installed the survivor's join barrier reports
    // Error::Timeout instead of blocking forever.
    use std::time::Duration;
    use theano_mgpu::comm::collective::Collective;

    let mut fabrics = build_fabric(2, &[TransportKind::HostStaged]);
    let stalled = fabrics.remove(1);
    let mut survivor = fabrics.remove(0);
    survivor.set_io_deadline(Some(Duration::from_millis(40))).unwrap();
    let t = std::thread::spawn(move || {
        let mut ex = GradExchanger::new(survivor, 12, 4, false);
        ex.grad_ready(0, &[1.0; 12]).unwrap();
        ex.join().map(|g| g.to_vec())
    });
    let res = t.join().unwrap();
    assert!(matches!(res, Err(Error::Timeout(_))), "want timeout, got {res:?}");
    // Only now does the peer go away: the whole round it was alive.
    drop(stalled);
}

#[test]
fn tcp_ring_stalled_peer_times_out_mid_round() {
    // Same failure over real sockets: two ranks rendezvous into a TCP
    // ring, then rank 1 wedges without sending its round.  Rank 0's
    // socket deadline must fire — a dead-quiet peer is a loud timeout
    // in the collective error path, never a hang.
    use std::net::TcpListener;
    use std::time::Duration;
    use theano_mgpu::comm::collective::Collective;
    use theano_mgpu::comm::{ring_over_tcp, RendezvousCfg, FRESH_RUN};

    let addrs: Vec<String> = {
        let ls: Vec<TcpListener> =
            (0..2).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
        ls.iter().map(|l| l.local_addr().unwrap().to_string()).collect()
    };
    let peers1 = addrs.clone();
    let h1 = std::thread::spawn(move || {
        let rc = RendezvousCfg {
            rank: 1,
            peers: &peers1,
            fingerprint: 7,
            resume_step: FRESH_RUN,
            connect_timeout: Duration::from_secs(10),
            io_timeout: Duration::from_secs(10),
        };
        let ring = ring_over_tcp(&rc).unwrap();
        // Wedge: hold the sockets open, contribute nothing.
        std::thread::sleep(Duration::from_millis(800));
        drop(ring);
    });
    let rc = RendezvousCfg {
        rank: 0,
        peers: &addrs,
        fingerprint: 7,
        resume_step: FRESH_RUN,
        connect_timeout: Duration::from_secs(10),
        io_timeout: Duration::from_millis(100),
    };
    let mut ring = ring_over_tcp(&rc).unwrap();
    let mut buf = vec![1.0f32; 8];
    let res = ring.all_reduce_flat(&mut buf);
    assert!(matches!(res, Err(Error::Timeout(_))), "want timeout, got {res:?}");
    h1.join().unwrap();
}

#[test]
fn dataset_too_small_for_batch_panics_cleanly() {
    let dir = fresh_dataset("small", 10);
    let lcfg = LoaderCfg {
        data_dir: &dir,
        split: "val", // 32 examples
        batch: 64,
        crop_hw: 32,
        worker: 0,
        workers: 1,
        seed: 1,
        train_augment: false,
        verify_shards: false,
    };
    // EpochSampler asserts dataset >= batch*workers.
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _ = theano_mgpu::data::loader::SerialLoader::new(&lcfg);
    }));
    assert!(res.is_err());
}
