//! Determinism-first contract of the intra-op parallel native path:
//! every pool kernel must be **bit-identical** across lane counts
//! (fixed shape-derived tile/chunk boundaries, disjoint writes,
//! fixed-order chunk reductions), and the packed-GEMM / forward / FC
//! kernels additionally bitwise match their serial forms.  The capstone
//! pins the full `train_step` — loss and every parameter/momentum —
//! across `threads ∈ {1, 2, 4}`, which is what keeps the N-replica
//! divergence invariants valid under intra-op parallelism.
//!
//! Shapes are deliberately awkward: single rows/examples, dims under
//! one `MR`/`NR` register tile, `k = 1`, primes, dims exactly on and
//! one past the `MC`/`KC`/`NC` cache-block (tile == chunk) boundaries,
//! and data shorter than one `ELEMWISE_CHUNK`.

use theano_mgpu::backend::native::gemm::{
    matmul_nn, matmul_nn_ws_with, matmul_nt, matmul_nt_ws_with, matmul_tn, matmul_tn_ws_with,
    par_matmul_nn, par_matmul_nt, par_matmul_tn, KC, MC, MR, NC, NR, PackBuf,
};
use theano_mgpu::backend::native::layers::{
    conv2d_backward, conv2d_backward_pool, conv2d_forward, conv2d_forward_pool, dropout_backward,
    dropout_forward, fc_backward, fc_backward_pool, fc_forward, fc_forward_pool, im2col,
    lrn_backward, lrn_backward_pool, lrn_forward, lrn_forward_pool, maxpool_backward,
    maxpool_backward_pool, maxpool_forward, maxpool_forward_pool, relu_backward,
    relu_backward_pool, relu_forward, relu_forward_pool, Conv2dShape, ConvScratch, FcShape,
    LrnShape, PoolShape,
};
use theano_mgpu::backend::native::pool::{shape_chunks, ComputePool, ELEMWISE_CHUNK, MAX_CHUNKS};
use theano_mgpu::backend::native::simd::{Isa, MicroKernel};
use theano_mgpu::backend::{GradSink, NativeBackend, StepBackend};
use theano_mgpu::comm::collective::build_fabric;
use theano_mgpu::comm::GradExchanger;
use theano_mgpu::config::TransportKind;
use theano_mgpu::params::ParamStore;
use theano_mgpu::sim::flops::{alexnet_micro, LrnSpec};
use theano_mgpu::tensor::{HostTensor, Shape};
use theano_mgpu::util::math::transpose;
use theano_mgpu::util::Pcg32;

const LANE_COUNTS: [usize; 3] = [1, 2, 4];

fn randn(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    let mut v = vec![0.0; n];
    rng.fill_normal(&mut v, 1.0);
    // Sprinkle zeros so zero-padding-adjacent values stay exercised.
    for (i, x) in v.iter_mut().enumerate() {
        if i % 7 == 0 {
            *x = 0.0;
        }
    }
    v
}

fn max_rel_err(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| theano_mgpu::util::math::rel_err(*x, *y))
        .fold(0.0, f32::max)
}

/// Packing/tiling edge shapes: below one register tile (`m < MR`,
/// `n < NR`), `k = 1`, primes, exactly one `MC×KC×NC` macro tile
/// (tile == chunk boundaries), one past every block edge, and a
/// `MAX_CHUNKS`-row shape from the batch-chunk world.  Serial and
/// parallel must agree **bitwise** at every lane count.
#[test]
fn gemm_tiles_match_serial_bitwise_at_edge_shapes() {
    let shapes = [
        (1, 1, 1),
        (MR - 1, 3, NR - 1),
        (MR, 1, NR),
        (5, 1, 2),
        (13, 11, 17),
        (MAX_CHUNKS, 5, 9),
        (MC, KC, NC),
        (MC + 1, KC + 1, NC + 1),
        (3, 64, 520),
    ];
    let mut rng = Pcg32::seeded(21);
    for threads in LANE_COUNTS {
        let pool = ComputePool::new(threads);
        let mut ws = PackBuf::default();
        for (m, k, n) in shapes {
            let a = randn(&mut rng, m * k);
            let at = transpose(m, k, &a);
            let b = randn(&mut rng, k * n);
            let bt = transpose(k, n, &b);

            let mut want = vec![0.1; m * n];
            matmul_nn(m, k, n, &a, &b, &mut want);
            let mut got = vec![0.1; m * n];
            par_matmul_nn(&pool, m, k, n, &a, &b, &mut got, &mut ws);
            assert_eq!(want, got, "nn {m}x{k}x{n} t{threads}");

            let mut want = vec![-0.2; m * n];
            matmul_nt(m, k, n, &a, &bt, &mut want);
            let mut got = vec![-0.2; m * n];
            par_matmul_nt(&pool, m, k, n, &a, &bt, &mut got, &mut ws);
            assert_eq!(want, got, "nt {m}x{k}x{n} t{threads}");

            let mut want = vec![0.0; m * n];
            matmul_tn(m, k, n, &at, &b, &mut want);
            let mut got = vec![0.0; m * n];
            par_matmul_tn(&pool, m, k, n, &at, &b, &mut got, &mut ws);
            assert_eq!(want, got, "tn {m}x{k}x{n} t{threads}");
        }
    }
}

/// The serial==parallel bitwise contract holds **per-ISA**: for every
/// microkernel the host can run (explicitly pinned per pool, the same
/// mechanism the `TMG_GEMM_ISA` override resolves to), serial and
/// parallel agree bitwise at lanes {1, 2, 4}.  On x86_64 CI this sweeps
/// both the AVX2+FMA kernel and the portable fallback; ISAs the host
/// lacks are skipped (their dispatch-degradation behavior is covered by
/// the `simd` unit tests).
#[test]
fn gemm_is_bitwise_serial_equal_for_every_available_isa() {
    let shapes = [(MR - 1, 3, NR - 1), (13, 11, 17), (MC + 1, KC + 1, NC + 1)];
    let mut rng = Pcg32::seeded(33);
    for isa in [Isa::Avx2, Isa::Neon, Isa::Scalar] {
        if !isa.available() {
            continue;
        }
        let kern = MicroKernel::for_isa(isa);
        for threads in LANE_COUNTS {
            let pool = ComputePool::with_kernel(threads, kern);
            let mut ws = PackBuf::default();
            let mut serial_ws = PackBuf::default();
            for (m, k, n) in shapes {
                let a = randn(&mut rng, m * k);
                let at = transpose(m, k, &a);
                let b = randn(&mut rng, k * n);
                let bt = transpose(k, n, &b);

                let mut want = vec![0.1; m * n];
                matmul_nn_ws_with(kern, m, k, n, &a, &b, &mut want, &mut serial_ws);
                let mut got = vec![0.1; m * n];
                par_matmul_nn(&pool, m, k, n, &a, &b, &mut got, &mut ws);
                assert_eq!(want, got, "nn {isa:?} {m}x{k}x{n} t{threads}");

                let mut want = vec![-0.2; m * n];
                matmul_nt_ws_with(kern, m, k, n, &a, &bt, &mut want, &mut serial_ws);
                let mut got = vec![-0.2; m * n];
                par_matmul_nt(&pool, m, k, n, &a, &bt, &mut got, &mut ws);
                assert_eq!(want, got, "nt {isa:?} {m}x{k}x{n} t{threads}");

                let mut want = vec![0.0; m * n];
                matmul_tn_ws_with(kern, m, k, n, &at, &b, &mut want, &mut serial_ws);
                let mut got = vec![0.0; m * n];
                par_matmul_tn(&pool, m, k, n, &at, &b, &mut got, &mut ws);
                assert_eq!(want, got, "tn {isa:?} {m}x{k}x{n} t{threads}");
            }
        }
    }
}

/// Cross-ISA agreement is rounding-level, not bitwise: FMA kernels fuse
/// each multiply-add into a single rounding step, so results drift from
/// the portable kernel by ULPs.  1e-4 max `rel_err` (denominator
/// floored at 1) is far above that drift and far below any real defect
/// on these unit-normal operands.
#[test]
fn simd_and_portable_kernels_agree_to_rounding() {
    let fallback = MicroKernel::for_isa(Isa::Scalar);
    let mut rng = Pcg32::seeded(34);
    let (m, k, n) = (MC + 2, KC + 3, NC + 5);
    let a = randn(&mut rng, m * k);
    let b = randn(&mut rng, k * n);
    let mut want = vec![0.0; m * n];
    let mut ws = PackBuf::default();
    matmul_nn_ws_with(fallback, m, k, n, &a, &b, &mut want, &mut ws);
    for isa in [Isa::Avx2, Isa::Neon] {
        if !isa.available() {
            continue;
        }
        let mut got = vec![0.0; m * n];
        matmul_nn_ws_with(MicroKernel::for_isa(isa), m, k, n, &a, &b, &mut got, &mut ws);
        let e = max_rel_err(&got, &want);
        assert!(e < 1e-4, "{isa:?} vs portable: max rel err {e}");
    }
}

/// Empty ragged eval batches (`m == 0`) and empty outputs (`n == 0`)
/// must dispatch nothing at any lane count — the guard mirroring the
/// long-standing `n == 0` early return.
#[test]
fn par_gemm_handles_empty_row_and_column_counts() {
    for threads in LANE_COUNTS {
        let pool = ComputePool::new(threads);
        let mut ws = PackBuf::default();
        let b = vec![1.0; 3 * 4];
        let mut c: Vec<f32> = vec![];
        par_matmul_nn(&pool, 0, 3, 4, &[], &b, &mut c, &mut ws);
        par_matmul_nt(&pool, 0, 3, 4, &[], &b, &mut c, &mut ws);
        par_matmul_tn(&pool, 0, 3, 4, &[], &b, &mut c, &mut ws);
        let a = vec![1.0; 2 * 3];
        par_matmul_nn(&pool, 2, 3, 0, &a, &[], &mut c, &mut ws);
        par_matmul_nt(&pool, 2, 3, 0, &a, &[], &mut c, &mut ws);
        par_matmul_tn(&pool, 2, 3, 0, &transpose(2, 3, &a), &[], &mut c, &mut ws);
        assert!(c.is_empty(), "t{threads}");
    }
}

/// Conv geometry used by the batch-sweep tests.
fn conv_shape(batch: usize) -> Conv2dShape {
    Conv2dShape { batch, cin: 2, cout: 3, k: 3, stride: 2, pad: 1, in_hw: 7, out_hw: 4, groups: 1 }
}

fn conv_scratch(lanes: usize, batch: usize, s: &Conv2dShape) -> ConvScratch {
    let mut scratch = ConvScratch::default();
    scratch.ensure(lanes, shape_chunks(batch).0, s.col_elems(), s.w_elems(), s.cout);
    scratch
}

#[test]
fn conv_forward_matches_serial_bitwise_at_awkward_batches() {
    let mut rng = Pcg32::seeded(31);
    // 1 example, prime, exactly MAX_CHUNKS, and > MAX_CHUNKS (chunk 2).
    for batch in [1, 5, MAX_CHUNKS, MAX_CHUNKS + 1] {
        let s = conv_shape(batch);
        let x = randn(&mut rng, batch * s.in_elems());
        let w = randn(&mut rng, s.w_elems());
        let b = randn(&mut rng, s.cout);
        let mut want = vec![0.0; batch * s.out_elems()];
        let mut col = vec![0.0; s.col_elems()];
        conv2d_forward(&x, &w, &b, &mut want, &mut col, &s);
        for threads in LANE_COUNTS {
            let pool = ComputePool::new(threads);
            let mut scratch = conv_scratch(pool.lanes(), batch, &s);
            let mut cache = vec![0.0; batch * s.col_elems()];
            let mut got = vec![0.0; want.len()];
            conv2d_forward_pool(
                &pool,
                &x,
                &w,
                &b,
                &mut got,
                Some(cache.as_mut_slice()),
                &mut scratch,
                &s,
            );
            assert_eq!(want, got, "conv fwd b{batch} t{threads}");
            // The eval path (no cache, per-lane staging) is bitwise
            // identical too.
            let mut got_eval = vec![0.0; want.len()];
            conv2d_forward_pool(&pool, &x, &w, &b, &mut got_eval, None, &mut scratch, &s);
            assert_eq!(want, got_eval, "conv fwd (no cache) b{batch} t{threads}");
            // The cache holds exactly each example's im2col columns —
            // the contract the backward pass's reuse depends on.
            let mut want_col = vec![0.0; s.col_elems()];
            for bi in 0..batch {
                im2col(&x[bi * s.in_elems()..(bi + 1) * s.in_elems()], &s, &mut want_col);
                assert_eq!(
                    want_col,
                    &cache[bi * s.col_elems()..(bi + 1) * s.col_elems()],
                    "cache b{batch} t{threads} example {bi}"
                );
            }
        }
    }
}

#[test]
fn conv_backward_is_lane_count_invariant_and_close_to_serial() {
    let mut rng = Pcg32::seeded(37);
    for batch in [1, 5, MAX_CHUNKS, MAX_CHUNKS + 1] {
        let s = conv_shape(batch);
        let x = randn(&mut rng, batch * s.in_elems());
        let w = randn(&mut rng, s.w_elems());
        let dy = randn(&mut rng, batch * s.out_elems());

        // Serial reference (example-order accumulation, columns
        // recomputed from x).
        let mut dw_ref = vec![0.0; w.len()];
        let mut db_ref = vec![0.0; s.cout];
        let mut dx_ref = vec![0.0; x.len()];
        let mut col = vec![0.0; s.col_elems()];
        let mut dcol = vec![0.0; s.col_elems()];
        conv2d_backward(
            &x,
            &w,
            &dy,
            &mut dw_ref,
            &mut db_ref,
            &mut dx_ref,
            &mut col,
            &mut dcol,
            &s,
        );

        // The pool path consumes the forward pass's cached columns.
        let mut cache = vec![0.0; batch * s.col_elems()];
        for bi in 0..batch {
            let xe = &x[bi * s.in_elems()..(bi + 1) * s.in_elems()];
            im2col(xe, &s, &mut cache[bi * s.col_elems()..(bi + 1) * s.col_elems()]);
        }

        let mut first: Option<(Vec<f32>, Vec<f32>, Vec<f32>)> = None;
        for threads in LANE_COUNTS {
            let pool = ComputePool::new(threads);
            let mut scratch = conv_scratch(pool.lanes(), batch, &s);
            let mut dw = vec![0.0; w.len()];
            let mut db = vec![0.0; s.cout];
            let mut dx = vec![0.0; x.len()];
            conv2d_backward_pool(
                &pool,
                &w,
                &dy,
                &mut dw,
                &mut db,
                &mut dx,
                &cache,
                &mut scratch,
                &s,
            );
            // dx is per-example: bitwise equal even to the serial path.
            assert_eq!(dx_ref, dx, "conv dx b{batch} t{threads}");
            // dw/db regroup the example sum by chunk: equal to f32
            // rounding vs serial, *bitwise* across lane counts.
            assert!(max_rel_err(&dw_ref, &dw) < 1e-4, "conv dw b{batch} t{threads}");
            assert!(max_rel_err(&db_ref, &db) < 1e-4, "conv db b{batch} t{threads}");
            match &first {
                None => first = Some((dw, db, dx)),
                Some((dw1, db1, dx1)) => {
                    assert_eq!(dw1, &dw, "conv dw lanes b{batch} t{threads}");
                    assert_eq!(db1, &db, "conv db lanes b{batch} t{threads}");
                    assert_eq!(dx1, &dx, "conv dx lanes b{batch} t{threads}");
                }
            }
        }
    }
}

/// Grouped variant of [`conv_shape`]: 2 groups over 4 in / 6 out
/// channels, same awkward spatial geometry.
fn gconv_shape(batch: usize) -> Conv2dShape {
    Conv2dShape { batch, cin: 4, cout: 6, k: 3, stride: 2, pad: 1, in_hw: 7, out_hw: 4, groups: 2 }
}

#[test]
fn grouped_conv_matches_serial_bitwise_at_awkward_batches() {
    let mut rng = Pcg32::seeded(51);
    for batch in [1, 5, MAX_CHUNKS, MAX_CHUNKS + 1] {
        let s = gconv_shape(batch);
        let x = randn(&mut rng, batch * s.in_elems());
        let w = randn(&mut rng, s.w_elems());
        let b = randn(&mut rng, s.cout);
        let dy = randn(&mut rng, batch * s.out_elems());

        let mut want = vec![0.0; batch * s.out_elems()];
        let mut col = vec![0.0; s.col_elems()];
        conv2d_forward(&x, &w, &b, &mut want, &mut col, &s);
        let mut dw_ref = vec![0.0; w.len()];
        let mut db_ref = vec![0.0; s.cout];
        let mut dx_ref = vec![0.0; x.len()];
        let mut dcol = vec![0.0; s.col_elems()];
        conv2d_backward(&x, &w, &dy, &mut dw_ref, &mut db_ref, &mut dx_ref, &mut col, &mut dcol, &s);

        let mut first: Option<(Vec<f32>, Vec<f32>)> = None;
        for threads in LANE_COUNTS {
            let pool = ComputePool::new(threads);
            let mut scratch = conv_scratch(pool.lanes(), batch, &s);
            let mut cache = vec![0.0; batch * s.col_elems()];
            let mut got = vec![0.0; want.len()];
            conv2d_forward_pool(
                &pool,
                &x,
                &w,
                &b,
                &mut got,
                Some(cache.as_mut_slice()),
                &mut scratch,
                &s,
            );
            assert_eq!(want, got, "gconv fwd b{batch} t{threads}");
            let mut dw = vec![0.0; w.len()];
            let mut db = vec![0.0; s.cout];
            let mut dx = vec![0.0; x.len()];
            conv2d_backward_pool(&pool, &w, &dy, &mut dw, &mut db, &mut dx, &cache, &mut scratch, &s);
            // Per-example dx is bitwise serial-equal; dw/db regroup the
            // example sum by chunk (rounding-level vs serial, bitwise
            // across lane counts).
            assert_eq!(dx_ref, dx, "gconv dx b{batch} t{threads}");
            assert!(max_rel_err(&dw_ref, &dw) < 1e-4, "gconv dw b{batch} t{threads}");
            assert!(max_rel_err(&db_ref, &db) < 1e-4, "gconv db b{batch} t{threads}");
            match &first {
                None => first = Some((dw, db)),
                Some((dw1, db1)) => {
                    assert_eq!(dw1, &dw, "gconv dw lanes b{batch} t{threads}");
                    assert_eq!(db1, &db, "gconv db lanes b{batch} t{threads}");
                }
            }
        }
    }
}

#[test]
fn lrn_matches_serial_bitwise_at_awkward_batches() {
    let mut rng = Pcg32::seeded(53);
    // channels < window, == window, and plenty past it.
    for (channels, radius) in [(3usize, 2usize), (5, 2), (11, 2)] {
        for batch in [1, 5, MAX_CHUNKS, MAX_CHUNKS + 1] {
            let s = LrnShape { batch, channels, hw: 3, radius, bias: 2.0, alpha: 0.3, beta: 0.75 };
            let x = randn(&mut rng, batch * s.elems());
            let dy = randn(&mut rng, batch * s.elems());
            let mut y_ref = vec![0.0; x.len()];
            lrn_forward(&x, &mut y_ref, &s);
            let mut dx_ref = vec![0.0; x.len()];
            lrn_backward(&x, &y_ref, &dy, &mut dx_ref, &s);
            for threads in LANE_COUNTS {
                let pool = ComputePool::new(threads);
                let mut y = vec![0.0; x.len()];
                lrn_forward_pool(&pool, &x, &mut y, &s);
                assert_eq!(y_ref, y, "lrn fwd c{channels} b{batch} t{threads}");
                let mut dx = vec![0.0; x.len()];
                lrn_backward_pool(&pool, &x, &y_ref, &dy, &mut dx, &s);
                assert_eq!(dx_ref, dx, "lrn bwd c{channels} b{batch} t{threads}");
            }
        }
    }
}

/// The per-ISA serial==parallel contract at the *grouped* conv panel
/// geometry: per-group GEMMs see `cout/G × (cin/G)·k² × ohw` operands
/// (and their nt/tn backward transposes), which are far narrower than
/// the ungrouped panels.  For every microkernel the host can run, the
/// pinned-kernel parallel GEMMs must bitwise match the pinned-kernel
/// serial forms at these shapes and every lane count.
#[test]
fn grouped_panel_gemms_are_bitwise_serial_equal_for_every_available_isa() {
    let s = gconv_shape(1);
    let gcout = s.cout / s.groups;
    let ck2 = (s.cin / s.groups) * s.k * s.k;
    let ohw = s.out_hw * s.out_hw;
    // Forward (nn), dW (nt), and dcol (tn) panel shapes.
    let shapes = [(gcout, ck2, ohw), (gcout, ohw, ck2), (ck2, gcout, ohw)];
    let mut rng = Pcg32::seeded(57);
    for isa in [Isa::Avx2, Isa::Neon, Isa::Scalar] {
        if !isa.available() {
            continue;
        }
        let kern = MicroKernel::for_isa(isa);
        for threads in LANE_COUNTS {
            let pool = ComputePool::with_kernel(threads, kern);
            let mut ws = PackBuf::default();
            let mut serial_ws = PackBuf::default();
            for (m, k, n) in shapes {
                let a = randn(&mut rng, m * k);
                let at = transpose(m, k, &a);
                let b = randn(&mut rng, k * n);
                let bt = transpose(k, n, &b);

                let mut want = vec![0.0; m * n];
                matmul_nn_ws_with(kern, m, k, n, &a, &b, &mut want, &mut serial_ws);
                let mut got = vec![0.0; m * n];
                par_matmul_nn(&pool, m, k, n, &a, &b, &mut got, &mut ws);
                assert_eq!(want, got, "gpanel nn {isa:?} {m}x{k}x{n} t{threads}");

                let mut want = vec![0.0; m * n];
                matmul_nt_ws_with(kern, m, k, n, &a, &bt, &mut want, &mut serial_ws);
                let mut got = vec![0.0; m * n];
                par_matmul_nt(&pool, m, k, n, &a, &bt, &mut got, &mut ws);
                assert_eq!(want, got, "gpanel nt {isa:?} {m}x{k}x{n} t{threads}");

                let mut want = vec![0.0; m * n];
                matmul_tn_ws_with(kern, m, k, n, &at, &b, &mut want, &mut serial_ws);
                let mut got = vec![0.0; m * n];
                par_matmul_tn(&pool, m, k, n, &at, &b, &mut got, &mut ws);
                assert_eq!(want, got, "gpanel tn {isa:?} {m}x{k}x{n} t{threads}");
            }
        }
    }
}

#[test]
fn maxpool_matches_serial_bitwise() {
    let mut rng = Pcg32::seeded(41);
    // planes = batch*channels: 1, prime, > MAX_CHUNKS.
    for (batch, channels) in [(1, 1), (1, 13), (3, 7)] {
        let s = PoolShape { batch, channels, in_hw: 6, window: 2, stride: 2, out_hw: 3 };
        let planes = batch * channels;
        let x = randn(&mut rng, planes * s.in_hw * s.in_hw);
        let out = planes * s.out_hw * s.out_hw;
        let mut y_ref = vec![0.0; out];
        let mut am_ref = vec![0u32; out];
        maxpool_forward(&x, &mut y_ref, &mut am_ref, &s);
        let dy = randn(&mut rng, out);
        let mut dx_ref = vec![0.0; x.len()];
        maxpool_backward(&dy, &am_ref, &mut dx_ref, &s);
        for threads in LANE_COUNTS {
            let pool = ComputePool::new(threads);
            let mut y = vec![0.0; out];
            let mut am = vec![0u32; out];
            maxpool_forward_pool(&pool, &x, &mut y, &mut am, &s);
            assert_eq!(y_ref, y, "pool fwd {batch}x{channels} t{threads}");
            assert_eq!(am_ref, am, "pool argmax {batch}x{channels} t{threads}");
            let mut dx = vec![0.0; x.len()];
            maxpool_backward_pool(&pool, &dy, &am, &mut dx, &s);
            assert_eq!(dx_ref, dx, "pool bwd {batch}x{channels} t{threads}");
        }
    }
}

#[test]
fn fc_and_relu_match_serial_bitwise() {
    let mut rng = Pcg32::seeded(43);
    // batch 1, prime dims, and dout == MAX_CHUNKS; lengths both under
    // and over one ELEMWISE_CHUNK for the elementwise sweeps.
    for (batch, din, dout) in [(1, 11, 3), (7, 29, MAX_CHUNKS), (5, ELEMWISE_CHUNK / 4, 9)] {
        let s = FcShape { batch, din, dout };
        let x = randn(&mut rng, batch * din);
        let w = randn(&mut rng, dout * din);
        let b = randn(&mut rng, dout);
        let dy = randn(&mut rng, batch * dout);

        let mut y_ref = vec![0.0; batch * dout];
        fc_forward(&x, &w, &b, &mut y_ref, &s);
        let mut dw_ref = vec![0.0; w.len()];
        let mut db_ref = vec![0.0; dout];
        let mut dx_ref = vec![0.0; x.len()];
        fc_backward(&x, &w, &dy, &mut dw_ref, &mut db_ref, &mut dx_ref, &s);

        let mut relu_ref = y_ref.clone();
        relu_forward(&mut relu_ref);
        let mut drelu_ref = dy.clone();
        relu_backward(&relu_ref, &mut drelu_ref);

        for threads in LANE_COUNTS {
            let pool = ComputePool::new(threads);
            let mut ws = PackBuf::default();
            let mut y = vec![0.0; batch * dout];
            fc_forward_pool(&pool, &x, &w, &b, &mut y, &mut ws, &s);
            assert_eq!(y_ref, y, "fc fwd {batch}x{din}x{dout} t{threads}");
            let mut dw = vec![0.0; w.len()];
            let mut db = vec![0.0; dout];
            let mut dx = vec![0.0; x.len()];
            fc_backward_pool(&pool, &x, &w, &dy, &mut dw, &mut db, &mut dx, &mut ws, &s);
            assert_eq!(dw_ref, dw, "fc dw t{threads}");
            assert_eq!(db_ref, db, "fc db t{threads}");
            assert_eq!(dx_ref, dx, "fc dx t{threads}");

            let mut r = y_ref.clone();
            relu_forward_pool(&pool, &mut r);
            assert_eq!(relu_ref, r, "relu fwd t{threads}");
            let mut dr = dy.clone();
            relu_backward_pool(&pool, &relu_ref, &mut dr);
            assert_eq!(drelu_ref, dr, "relu bwd t{threads}");
        }
    }
}

#[test]
fn dropout_is_lane_count_invariant_across_chunk_boundaries() {
    // Longer than 2 chunks so multiple per-chunk streams interleave;
    // also one short (sub-chunk) sweep.
    for n in [100, 2 * ELEMWISE_CHUNK + 33] {
        let mut first: Option<(Vec<f32>, Vec<f32>)> = None;
        for threads in LANE_COUNTS {
            let pool = ComputePool::new(threads);
            let mut a = vec![1.0f32; n];
            let mut mask = vec![0.0f32; n];
            dropout_forward(&pool, &mut a, &mut mask, 0.5, 99, 1);
            let mut da = vec![2.0f32; n];
            dropout_backward(&pool, &mut da, &mask);
            for (g, &av) in da.iter().zip(&a) {
                assert_eq!(*g, 2.0 * if av == 0.0 { 0.0 } else { 2.0 }, "replay");
            }
            match &first {
                None => first = Some((a, mask)),
                Some((a1, m1)) => {
                    assert_eq!(a1, &a, "dropout acts n{n} t{threads}");
                    assert_eq!(m1, &mask, "dropout mask n{n} t{threads}");
                }
            }
        }
    }
}

/// The capstone: a multi-step training run — forward, backward,
/// dropout, SGD-momentum update — is bit-identical for
/// `threads ∈ {1, 2, 4}`: same losses, same parameters, same momenta.
#[test]
fn train_step_is_bitwise_identical_across_thread_counts() {
    let arch = alexnet_micro();
    let mut rng = Pcg32::seeded(7);
    // Batch 6: not a divisor-friendly size, exercises short chunks.
    let batch = 6;
    let images = HostTensor::rand_normal(Shape::of(&[batch, 3, 32, 32]), &mut rng, 1.0);
    let labels: Vec<i32> =
        (0..batch).map(|_| rng.below(arch.num_classes as u32) as i32).collect();

    let run = |threads: usize| {
        let mut backend = NativeBackend::with_threads(&arch, 0.5, threads);
        assert_eq!(backend.threads(), threads);
        let mut store = ParamStore::init(&backend.model().params, 11);
        let mut losses = Vec::new();
        for step in 0..4 {
            let out = backend.train_step(&images, &labels, 0.02, 100 + step, &mut store).unwrap();
            losses.push(out.loss);
        }
        let eval = backend.eval_batch(&images, &labels, &store).unwrap();
        (losses, eval.loss, store)
    };

    let (losses1, eval1, store1) = run(1);
    assert!(losses1.iter().all(|l| l.is_finite()));
    for threads in [2, 4] {
        let (losses_t, eval_t, store_t) = run(threads);
        assert_eq!(losses1, losses_t, "losses diverged at {threads} threads");
        assert_eq!(eval1, eval_t, "eval loss diverged at {threads} threads");
        assert_eq!(
            store1.max_divergence(&store_t),
            0.0,
            "params/momenta diverged at {threads} threads"
        );
    }
}

/// The capstone again, through the grouped-conv and LRN plan ops: a
/// micro arch with LRN after conv1 and 2-group conv2 must train
/// bit-identically for `threads ∈ {1, 2, 4}` — the acceptance bar for
/// the faithful-AlexNet structure under intra-op parallelism.
#[test]
fn grouped_lrn_train_step_is_bitwise_identical_across_thread_counts() {
    let mut arch = alexnet_micro();
    arch.convs[0].lrn = Some(LrnSpec::krizhevsky());
    arch.convs[1].groups = 2;
    let mut rng = Pcg32::seeded(9);
    let batch = 6;
    let images = HostTensor::rand_normal(Shape::of(&[batch, 3, 32, 32]), &mut rng, 1.0);
    let labels: Vec<i32> =
        (0..batch).map(|_| rng.below(arch.num_classes as u32) as i32).collect();

    let run = |threads: usize| {
        let mut backend = NativeBackend::with_threads(&arch, 0.5, threads);
        let mut store = ParamStore::init(&backend.model().params, 11);
        let mut losses = Vec::new();
        for step in 0..4 {
            let out = backend.train_step(&images, &labels, 0.02, 100 + step, &mut store).unwrap();
            losses.push(out.loss);
        }
        let eval = backend.eval_batch(&images, &labels, &store).unwrap();
        (losses, eval.loss, store)
    };

    let (losses1, eval1, store1) = run(1);
    assert!(losses1.iter().all(|l| l.is_finite()));
    for threads in [2, 4] {
        let (losses_t, eval_t, store_t) = run(threads);
        assert_eq!(losses1, losses_t, "grouped/lrn losses diverged at {threads} threads");
        assert_eq!(eval1, eval_t, "grouped/lrn eval loss diverged at {threads} threads");
        assert_eq!(
            store1.max_divergence(&store_t),
            0.0,
            "grouped/lrn params/momenta diverged at {threads} threads"
        );
    }
}

/// Collects staged gradients into one flat buffer (the single-replica
/// stand-in for the bucketed exchange).
struct FlatSink {
    flat: Vec<f32>,
    offsets: Vec<usize>,
}

impl GradSink for FlatSink {
    fn grad_ready(&mut self, param: usize, grad: &[f32]) -> theano_mgpu::error::Result<()> {
        let lo = self.offsets[param];
        self.flat[lo..lo + grad.len()].copy_from_slice(grad);
        Ok(())
    }
}

/// The staged protocol (`forward_backward` emitting gradients into a
/// sink, then `apply_update` from the flat buffer) must be bit-identical
/// to the fused `train_step` — at every lane count.  This is what makes
/// the overlapped exchange's math auditable: streaming only changes
/// *when* buckets ship, never what gets applied.
#[test]
fn staged_step_is_bitwise_identical_to_fused_across_thread_counts() {
    let arch = alexnet_micro();
    let mut rng = Pcg32::seeded(17);
    let batch = 6;
    let images = HostTensor::rand_normal(Shape::of(&[batch, 3, 32, 32]), &mut rng, 1.0);
    let labels: Vec<i32> =
        (0..batch).map(|_| rng.below(arch.num_classes as u32) as i32).collect();

    let fused = |threads: usize| {
        let mut backend = NativeBackend::with_threads(&arch, 0.5, threads);
        let mut store = ParamStore::init(&backend.model().params, 11);
        let mut losses = Vec::new();
        for step in 0..3 {
            let out = backend.train_step(&images, &labels, 0.02, 100 + step, &mut store).unwrap();
            losses.push(out.loss);
        }
        (losses, store)
    };
    let staged = |threads: usize| {
        let mut backend = NativeBackend::with_threads(&arch, 0.5, threads);
        assert!(backend.supports_staged_step());
        let mut offsets = vec![0usize];
        for p in &backend.model().params {
            offsets.push(offsets.last().unwrap() + p.shape.numel());
        }
        let total = *offsets.last().unwrap();
        let mut store = ParamStore::init(&backend.model().params, 11);
        let mut losses = Vec::new();
        for step in 0..3 {
            let mut sink = FlatSink { flat: vec![0.0; total], offsets: offsets.clone() };
            let out = backend
                .forward_backward(&images, &labels, 100 + step, &store, &mut sink)
                .unwrap();
            backend.apply_update(&mut store, 0.02, &sink.flat).unwrap();
            losses.push(out.loss);
        }
        (losses, store)
    };

    let (want_losses, want_store) = fused(1);
    for threads in LANE_COUNTS {
        let (losses, store) = staged(threads);
        assert_eq!(want_losses, losses, "staged losses diverged at {threads} threads");
        assert_eq!(
            want_store.max_divergence(&store),
            0.0,
            "staged params/momenta diverged at {threads} threads"
        );
    }
}

/// Bucket-boundary edge shapes over a real 2-rank fabric: a bucket
/// exactly the layout size, one past it, one exactly a tensor, and one
/// spanning a tensor boundary — streamed and serial — all reduce to the
/// same exact mean in the same bit pattern.
#[test]
fn bucket_layout_edges_reduce_bitwise_identically() {
    // Layout: three tensors of 12, 20, and 5 elements (37 total),
    // emitted last-tensor-first like a real backward pass.
    let cuts = [0usize, 12, 32, 37];
    let total = 37;
    for bucket_elems in [37usize, 38, 12, 16] {
        for stream in [false, true] {
            let fabrics = build_fabric(2, &[TransportKind::HostStaged; 2]);
            let joins: Vec<_> = fabrics
                .into_iter()
                .enumerate()
                .map(|(rank, fabric)| {
                    std::thread::spawn(move || {
                        let mut ex = GradExchanger::new(fabric, total, bucket_elems, stream);
                        let scale = if rank == 0 { 1.0 } else { 3.0 };
                        let grads: Vec<f32> =
                            (0..total).map(|i| (i as f32 + 1.0) * scale).collect();
                        for t in (0..3).rev() {
                            ex.grad_ready(cuts[t], &grads[cuts[t]..cuts[t + 1]]).unwrap();
                        }
                        let out = ex.join().unwrap().to_vec();
                        let stats = ex.finish().unwrap();
                        assert_eq!(stats.rounds, 1);
                        assert_eq!(stats.bucket_rounds, total.div_ceil(bucket_elems) as u64);
                        out
                    })
                })
                .collect();
            let outs: Vec<Vec<f32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
            // (v + 3v) / 2 = 2v, exact in f32 for these integer values.
            for out in &outs {
                for (i, &v) in out.iter().enumerate() {
                    assert_eq!(
                        v,
                        2.0 * (i as f32 + 1.0),
                        "bucket {bucket_elems} stream {stream} elem {i}"
                    );
                }
            }
            assert_eq!(outs[0], outs[1], "ranks must agree bitwise");
        }
    }
}
