//! Integration: full training jobs through the coordinator, on the
//! native CPU backend — no AOT artifacts required, so every test here
//! runs real compute on every machine.
//!
//! These are the paper's claims at micro scale:
//! - training converges (loss drops) on the synthetic corpus;
//! - 2-replica exchange keeps the replicas bit-synchronized (Fig 2),
//!   now over *real* gradients — including the full-state (params +
//!   momenta) invariant that was untestable while the step was
//!   artifact-gated;
//! - loader modes do not change the result, only the schedule (Fig 1);
//! - PCIe topology downgrades the transport, not the math (§4.4).

use std::path::{Path, PathBuf};

use theano_mgpu::config::{ClusterConfig, DataConfig, LoaderMode, TrainConfig, TransportKind};
use theano_mgpu::coordinator::trainer::{effective_transport, train, TrainSummary};
use theano_mgpu::data::synth::{generate_dataset, SynthSpec};

/// Shared micro dataset for all e2e tests (10 classes = micro model).
fn dataset(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tmg_e2e_{tag}_{}", std::process::id()));
    if !dir.join("meta.json").exists() {
        let spec = SynthSpec { classes: 10, hw: 36, seed: 42, ..Default::default() };
        generate_dataset(&dir, &spec, 640, 64, 320).unwrap();
    }
    dir
}

fn micro_cfg(tag: &str, steps: usize, workers: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.name = format!("e2e-{tag}");
    cfg.model = "alexnet-micro".into();
    cfg.backend = "native".into();
    // Dropout off: micro-scale runs are short, and determinism-of-math
    // assertions are easier to reason about without masking noise.
    cfg.dropout = 0.0;
    cfg.batch_per_worker = 8;
    cfg.steps = steps;
    cfg.log_every = 0;
    cfg.seed = 7;
    cfg.schedule.base_lr = 0.02;
    cfg.cluster = match workers {
        1 => ClusterConfig::single(),
        2 => ClusterConfig::pair_same_switch(),
        n => ClusterConfig { workers: n, switch_of_worker: vec![0; n] },
    };
    cfg.data = DataConfig {
        dir: dataset(tag),
        train_examples: 640,
        val_examples: 64,
        shard_examples: 320,
        seed: 42,
        stored_hw: 36,
    };
    cfg
}

fn tail_mean(s: &TrainSummary, n: usize) -> f32 {
    let t: Vec<f32> = s.losses.iter().rev().take(n).copied().collect();
    t.iter().sum::<f32>() / t.len().max(1) as f32
}

#[test]
fn single_worker_converges() {
    let cfg = micro_cfg("single", 60, 1);
    let s = train(&cfg).unwrap();
    assert_eq!(s.workers, 1);
    assert!(s.losses.iter().all(|l| l.is_finite()));
    let first = s.losses[0];
    let late = tail_mean(&s, 10);
    assert!(late < 0.75 * first, "loss {first} -> {late}");
    let eval = s.eval.expect("native backend always evaluates");
    assert!(eval.examples > 0);
    assert!(eval.top1_error() < 0.9, "top-1 error {}", eval.top1_error());
    assert!(eval.top5_error() <= eval.top1_error());
    // No peer to compare against: divergence is None, not 0-or-NaN.
    assert!(s.final_divergence.is_none());
}

#[test]
fn two_workers_stay_synchronized_and_converge() {
    let cfg = micro_cfg("pair", 30, 2);
    let s = train(&cfg).unwrap();
    assert_eq!(s.exchange_rounds, 30);
    // Fig-2 invariant over real gradients: period 1 with momenta
    // included means the summary reports *full-state* divergence
    // (params + momenta), and symmetric averaging keeps it at zero.
    let divergence = s.final_divergence.expect("2 workers report divergence");
    assert!(divergence < 1e-6, "replicas diverged: {divergence}");
    let first = s.losses[0];
    let late = tail_mean(&s, 10);
    assert!(late < 0.9 * first, "loss {first} -> {late}");
}

#[test]
fn two_workers_times_two_threads_stay_synchronized() {
    // Intra-op parallelism must compose with the collective fabric:
    // with 2 replicas each stepping on a 2-lane compute pool, the
    // strict full-state invariant (period 1 + momenta) still holds,
    // because the pool's chunked kernels are bit-identical for any
    // lane count.  Regression guard for the intra-op parallel backend.
    let mut cfg = micro_cfg("pair2x2", 20, 2);
    cfg.compute_threads = 2;
    let s = train(&cfg).unwrap();
    assert_eq!(s.exchange_rounds, 20);
    let divergence = s.final_divergence.expect("2 workers report divergence");
    assert!(
        divergence < 1e-6,
        "replicas diverged under intra-op parallelism: {divergence}"
    );
    let first = s.losses[0];
    let late = tail_mean(&s, 10);
    assert!(late < 0.9 * first, "loss {first} -> {late}");
}

#[test]
fn thread_count_does_not_change_the_math() {
    // The whole training job — loader, N=1 coordinator, backend —
    // yields identical losses for 1 and 2 intra-op threads.
    let mut a = micro_cfg("threadmath", 8, 1);
    a.compute_threads = 1;
    let mut b = micro_cfg("threadmath", 8, 1);
    b.compute_threads = 2;
    let sa = train(&a).unwrap();
    let sb = train(&b).unwrap();
    assert_eq!(sa.losses, sb.losses, "--threads must be semantically transparent");
}

#[test]
fn loader_mode_does_not_change_the_math() {
    let mut a = micro_cfg("loadermath", 8, 1);
    a.loader_mode = LoaderMode::Parallel;
    let mut b = micro_cfg("loadermath", 8, 1);
    b.loader_mode = LoaderMode::Serial;
    let sa = train(&a).unwrap();
    let sb = train(&b).unwrap();
    assert_eq!(sa.losses, sb.losses, "Fig-1 pipeline must be semantically transparent");
}

#[test]
fn transports_are_numerically_equivalent() {
    let mut base = micro_cfg("transport", 6, 2);
    let mut reference: Option<Vec<f32>> = None;
    for kind in [TransportKind::P2p, TransportKind::HostStaged, TransportKind::Serialized] {
        base.exchange.transport = kind;
        let s = train(&base).unwrap();
        assert!(s.final_divergence.unwrap() < 1e-6);
        match &reference {
            None => reference = Some(s.losses),
            Some(want) => assert_eq!(&s.losses, want, "{kind:?} changed results"),
        }
    }
}

#[test]
fn cross_switch_pair_falls_back_to_host_staged() {
    let mut cfg = micro_cfg("switch", 4, 2);
    cfg.cluster = ClusterConfig::pair_cross_switch();
    cfg.exchange.transport = TransportKind::P2p;
    assert_eq!(effective_transport(&cfg), TransportKind::HostStaged);
    // And training still works over the downgraded transport.
    let s = train(&cfg).unwrap();
    assert!(s.final_divergence.unwrap() < 1e-6);
}

#[test]
fn exchange_period_controls_divergence() {
    // With period > 1 and an off-cycle end, replicas end un-averaged.
    let mut cfg = micro_cfg("period", 5, 2);
    cfg.exchange.period = 2;
    let s = train(&cfg).unwrap();
    assert_eq!(s.exchange_rounds, 2); // after steps 2 and 4 only
    // Replicas are legitimately desynchronized here, so the summary
    // reports the params-only drift metric — still nonzero, because
    // step 5 trained on different minibatches without an exchange.
    assert!(
        s.final_divergence.unwrap() > 0.0,
        "step 5 is un-exchanged; replicas must differ"
    );
}

#[test]
fn momentum_exclusion_reports_param_drift_only() {
    // Momenta stay private when excluded from the exchange, so the
    // strict full-state invariant does not apply; params still agree
    // after every-step averaging.
    let mut cfg = micro_cfg("momexcl", 6, 2);
    cfg.exchange.include_momentum = false;
    let s = train(&cfg).unwrap();
    assert_eq!(s.exchange_rounds, 6);
    assert!(
        s.final_divergence.unwrap() < 1e-6,
        "params must agree after symmetric averaging"
    );
}

#[test]
fn three_worker_ring_trains() {
    // Odd N exercises the unequal-chunk path of the ring all-reduce.
    let cfg = micro_cfg("ring3", 4, 3);
    let s = train(&cfg).unwrap();
    assert_eq!(s.workers, 3);
    assert_eq!(s.exchange_rounds, 4);
    assert!(s.final_divergence.unwrap() < 1e-5);
}

#[test]
fn four_worker_ring_trains() {
    let cfg = micro_cfg("ring4", 6, 4);
    let s = train(&cfg).unwrap();
    assert_eq!(s.workers, 4);
    // Ring averaging synchronizes every replica each step.
    let divergence = s.final_divergence.expect("4 workers report divergence");
    assert!(divergence < 1e-5, "divergence {divergence}");
    // Per-phase collective stats are populated for N > 2.
    assert_eq!(s.collective.rounds, 6);
    assert!(s.collective.bytes_per_round > 0);
    assert!(s.collective.total_seconds() > 0.0);
}

#[test]
fn csv_metrics_written() {
    let mut cfg = micro_cfg("csv", 4, 1);
    let csv = std::env::temp_dir().join(format!("tmg_e2e_metrics_{}.csv", std::process::id()));
    cfg.metrics_csv = Some(csv.clone());
    train(&cfg).unwrap();
    let content = std::fs::read_to_string(&csv).unwrap();
    assert!(content.starts_with("step,worker,loss"));
    assert_eq!(content.lines().count(), 1 + 4);
}

#[test]
fn checkpoint_written_and_evaluable() {
    let mut cfg = micro_cfg("ckpt", 4, 1);
    let dir = std::env::temp_dir().join(format!("tmg_e2e_ckpt_{}", std::process::id()));
    cfg.checkpoint_dir = Some(dir.clone());
    train(&cfg).unwrap();
    let path = dir.join("e2e-ckpt_step4.ckpt");
    assert!(path.exists());

    // Reload and evaluate through the public backend API.
    let mut backend = theano_mgpu::backend::build_backend(&cfg).unwrap();
    let model = backend.model().clone();
    let mut store = theano_mgpu::params::ParamStore::init(&model.params, 0);
    let step = theano_mgpu::params::load_checkpoint(&path, &mut store).unwrap();
    assert_eq!(step, 4);
    let r = theano_mgpu::coordinator::eval::evaluate(&cfg, backend.as_mut(), &store, 2).unwrap();
    assert!(r.examples > 0);
    assert!(r.mean_loss.is_finite());
}

#[test]
fn xla_backend_without_artifacts_falls_back_and_trains() {
    // The pre-refactor dead end: an artifact backend tag with no
    // artifacts on disk.  The factory now falls back to native and the
    // job completes.
    let mut cfg = micro_cfg("fallback", 3, 1);
    cfg.backend = "refconv".into();
    cfg.artifacts_dir = Path::new("/nonexistent/artifacts").to_path_buf();
    let s = train(&cfg).unwrap();
    assert_eq!(s.steps, 3);
    assert!(s.losses.iter().all(|l| l.is_finite()));
}
