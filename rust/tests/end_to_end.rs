//! Integration: full training jobs through the coordinator, on the
//! native CPU backend — no AOT artifacts required, so every test here
//! runs real compute on every machine.
//!
//! These are the paper's claims at micro scale:
//! - training converges (loss drops) on the synthetic corpus;
//! - 2-replica exchange keeps the replicas bit-synchronized (Fig 2),
//!   now over *real* gradients — including the full-state (params +
//!   momenta) invariant that was untestable while the step was
//!   artifact-gated;
//! - loader modes do not change the result, only the schedule (Fig 1);
//! - PCIe topology downgrades the transport, not the math (§4.4).

use std::path::{Path, PathBuf};

use theano_mgpu::config::{
    ClusterConfig, DataConfig, LoaderMode, ResumeFrom, TrainConfig, TransportKind,
};
use theano_mgpu::coordinator::trainer::{effective_transport, train, TrainSummary};
use theano_mgpu::data::synth::{generate_dataset, SynthSpec};
use theano_mgpu::params::{load_checkpoint, ParamStore};

/// Shared micro dataset for all e2e tests (10 classes = micro model).
fn dataset(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tmg_e2e_{tag}_{}", std::process::id()));
    if !dir.join("meta.json").exists() {
        let spec = SynthSpec { classes: 10, hw: 36, seed: 42, ..Default::default() };
        generate_dataset(&dir, &spec, 640, 64, 320).unwrap();
    }
    dir
}

fn micro_cfg(tag: &str, steps: usize, workers: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.name = format!("e2e-{tag}");
    cfg.model = "alexnet-micro".into();
    cfg.backend = "native".into();
    // Dropout off: micro-scale runs are short, and determinism-of-math
    // assertions are easier to reason about without masking noise.
    cfg.dropout = 0.0;
    cfg.batch_per_worker = 8;
    cfg.steps = steps;
    cfg.log_every = 0;
    cfg.seed = 7;
    cfg.schedule.base_lr = 0.02;
    cfg.cluster = match workers {
        1 => ClusterConfig::single(),
        2 => ClusterConfig::pair_same_switch(),
        n => ClusterConfig { workers: n, switch_of_worker: vec![0; n] },
    };
    cfg.data = DataConfig {
        dir: dataset(tag),
        train_examples: 640,
        val_examples: 64,
        shard_examples: 320,
        seed: 42,
        stored_hw: 36,
    };
    cfg
}

fn tail_mean(s: &TrainSummary, n: usize) -> f32 {
    let t: Vec<f32> = s.losses.iter().rev().take(n).copied().collect();
    t.iter().sum::<f32>() / t.len().max(1) as f32
}

#[test]
fn single_worker_converges() {
    let cfg = micro_cfg("single", 60, 1);
    let s = train(&cfg).unwrap();
    assert_eq!(s.workers, 1);
    assert!(s.losses.iter().all(|l| l.is_finite()));
    let first = s.losses[0];
    let late = tail_mean(&s, 10);
    assert!(late < 0.75 * first, "loss {first} -> {late}");
    let eval = s.eval.expect("native backend always evaluates");
    assert!(eval.examples > 0);
    assert!(eval.top1_error() < 0.9, "top-1 error {}", eval.top1_error());
    assert!(eval.top5_error() <= eval.top1_error());
    // No peer to compare against: divergence is None, not 0-or-NaN.
    assert!(s.final_divergence.is_none());
}

#[test]
fn two_workers_stay_synchronized_and_converge() {
    let cfg = micro_cfg("pair", 30, 2);
    let s = train(&cfg).unwrap();
    assert_eq!(s.exchange_rounds, 30);
    // Fig-2 invariant over real gradients: period 1 with momenta
    // included means the summary reports *full-state* divergence
    // (params + momenta), and symmetric averaging keeps it at zero.
    let divergence = s.final_divergence.expect("2 workers report divergence");
    assert!(divergence < 1e-6, "replicas diverged: {divergence}");
    let first = s.losses[0];
    let late = tail_mean(&s, 10);
    assert!(late < 0.9 * first, "loss {first} -> {late}");
}

#[test]
fn two_workers_times_two_threads_stay_synchronized() {
    // Intra-op parallelism must compose with the collective fabric:
    // with 2 replicas each stepping on a 2-lane compute pool, the
    // strict full-state invariant (period 1 + momenta) still holds,
    // because the pool's chunked kernels are bit-identical for any
    // lane count.  Regression guard for the intra-op parallel backend.
    let mut cfg = micro_cfg("pair2x2", 20, 2);
    cfg.compute_threads = 2;
    let s = train(&cfg).unwrap();
    assert_eq!(s.exchange_rounds, 20);
    let divergence = s.final_divergence.expect("2 workers report divergence");
    assert!(
        divergence < 1e-6,
        "replicas diverged under intra-op parallelism: {divergence}"
    );
    let first = s.losses[0];
    let late = tail_mean(&s, 10);
    assert!(late < 0.9 * first, "loss {first} -> {late}");
}

#[test]
fn thread_count_does_not_change_the_math() {
    // The whole training job — loader, N=1 coordinator, backend —
    // yields identical losses for 1 and 2 intra-op threads.
    let mut a = micro_cfg("threadmath", 8, 1);
    a.compute_threads = 1;
    let mut b = micro_cfg("threadmath", 8, 1);
    b.compute_threads = 2;
    let sa = train(&a).unwrap();
    let sb = train(&b).unwrap();
    assert_eq!(sa.losses, sb.losses, "--threads must be semantically transparent");
}

#[test]
fn loader_mode_does_not_change_the_math() {
    let mut a = micro_cfg("loadermath", 8, 1);
    a.loader_mode = LoaderMode::Parallel;
    let mut b = micro_cfg("loadermath", 8, 1);
    b.loader_mode = LoaderMode::Serial;
    let sa = train(&a).unwrap();
    let sb = train(&b).unwrap();
    assert_eq!(sa.losses, sb.losses, "Fig-1 pipeline must be semantically transparent");
}

#[test]
fn transports_are_numerically_equivalent() {
    let mut base = micro_cfg("transport", 6, 2);
    let mut reference: Option<Vec<f32>> = None;
    for kind in [TransportKind::P2p, TransportKind::HostStaged, TransportKind::Serialized] {
        base.exchange.transport = kind;
        let s = train(&base).unwrap();
        assert!(s.final_divergence.unwrap() < 1e-6);
        match &reference {
            None => reference = Some(s.losses),
            Some(want) => assert_eq!(&s.losses, want, "{kind:?} changed results"),
        }
    }
}

#[test]
fn cross_switch_pair_falls_back_to_host_staged() {
    let mut cfg = micro_cfg("switch", 4, 2);
    cfg.cluster = ClusterConfig::pair_cross_switch();
    cfg.exchange.transport = TransportKind::P2p;
    assert_eq!(effective_transport(&cfg), TransportKind::HostStaged);
    // And training still works over the downgraded transport.
    let s = train(&cfg).unwrap();
    assert!(s.final_divergence.unwrap() < 1e-6);
}

#[test]
fn exchange_period_controls_divergence() {
    // With period > 1 and an off-cycle end, replicas end un-averaged.
    let mut cfg = micro_cfg("period", 5, 2);
    cfg.exchange.period = 2;
    let s = train(&cfg).unwrap();
    assert_eq!(s.exchange_rounds, 2); // after steps 2 and 4 only
    // Replicas are legitimately desynchronized here, so the summary
    // reports the params-only drift metric — still nonzero, because
    // step 5 trained on different minibatches without an exchange.
    assert!(
        s.final_divergence.unwrap() > 0.0,
        "step 5 is un-exchanged; replicas must differ"
    );
}

#[test]
fn momentum_exclusion_reports_param_drift_only() {
    // Momenta stay private when excluded from the exchange, so the
    // strict full-state invariant does not apply; params still agree
    // after every-step averaging.
    let mut cfg = micro_cfg("momexcl", 6, 2);
    cfg.exchange.include_momentum = false;
    let s = train(&cfg).unwrap();
    assert_eq!(s.exchange_rounds, 6);
    assert!(
        s.final_divergence.unwrap() < 1e-6,
        "params must agree after symmetric averaging"
    );
}

#[test]
fn three_worker_ring_trains() {
    // Odd N exercises the unequal-chunk path of the ring all-reduce.
    let cfg = micro_cfg("ring3", 4, 3);
    let s = train(&cfg).unwrap();
    assert_eq!(s.workers, 3);
    assert_eq!(s.exchange_rounds, 4);
    assert!(s.final_divergence.unwrap() < 1e-5);
}

#[test]
fn four_worker_ring_trains() {
    let cfg = micro_cfg("ring4", 6, 4);
    let s = train(&cfg).unwrap();
    assert_eq!(s.workers, 4);
    // Ring averaging synchronizes every replica each step.
    let divergence = s.final_divergence.expect("4 workers report divergence");
    assert!(divergence < 1e-5, "divergence {divergence}");
    // Per-phase collective stats are populated for N > 2.
    assert_eq!(s.collective.rounds, 6);
    assert!(s.collective.bytes_per_round > 0);
    assert!(s.collective.total_seconds() > 0.0);
}

#[test]
fn csv_metrics_written() {
    let mut cfg = micro_cfg("csv", 4, 1);
    let csv = std::env::temp_dir().join(format!("tmg_e2e_metrics_{}.csv", std::process::id()));
    cfg.metrics_csv = Some(csv.clone());
    train(&cfg).unwrap();
    let content = std::fs::read_to_string(&csv).unwrap();
    assert!(content.starts_with("step,worker,loss"));
    assert_eq!(content.lines().count(), 1 + 4);
}

#[test]
fn checkpoint_written_and_evaluable() {
    let mut cfg = micro_cfg("ckpt", 4, 1);
    let dir = std::env::temp_dir().join(format!("tmg_e2e_ckpt_{}", std::process::id()));
    cfg.checkpoint_dir = Some(dir.clone());
    train(&cfg).unwrap();
    let path = dir.join("e2e-ckpt_step4.ckpt");
    assert!(path.exists());

    // Reload and evaluate through the public backend API.
    let mut backend = theano_mgpu::backend::build_backend(&cfg).unwrap();
    let model = backend.model().clone();
    let mut store = theano_mgpu::params::ParamStore::init(&model.params, 0);
    let step = theano_mgpu::params::load_checkpoint(&path, &mut store).unwrap();
    assert_eq!(step, 4);
    let r = theano_mgpu::coordinator::eval::evaluate(&cfg, backend.as_mut(), &store, 2)
        .unwrap()
        .expect("val split present");
    assert!(r.examples > 0);
    assert!(r.mean_loss.is_finite());
}

/// Fresh checkpoint dir for one test phase.
fn ckpt_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tmg_e2e_ckd_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Load the final checkpoint a run wrote into `dir`.
fn load_final(cfg: &TrainConfig, dir: &Path) -> ParamStore {
    let model = theano_mgpu::backend::resolve_model(cfg).unwrap();
    let mut store = ParamStore::init(&model.params, 12345); // clobbered by the load
    let path = dir.join(format!("{}_step{}.ckpt", cfg.name, cfg.steps));
    load_checkpoint(&path, &mut store).unwrap();
    store
}

/// The acceptance criterion: `train 2N` and `train N -> kill -> resume N`
/// must produce *identical* final state (divergence 0.0), single worker.
#[test]
fn resume_is_bit_exact_single_worker() {
    let tag = "resume1";
    let straight_dir = ckpt_dir("straight1");
    let mut straight = micro_cfg(tag, 12, 1);
    // Dropout on: the per-step seeded masks must also replay exactly
    // (the resumed run re-derives step_seed from the absolute step).
    straight.dropout = 0.5;
    straight.checkpoint_dir = Some(straight_dir.clone());
    let s = train(&straight).unwrap();
    assert_eq!(s.resumed_from, None);
    let straight_losses = s.losses;

    // "Kill" after 6 steps (the run's final checkpoint doubles as the
    // kill-point snapshot), then resume to 12 in a second process life.
    let part_dir = ckpt_dir("part1");
    let mut part = micro_cfg(tag, 6, 1);
    part.dropout = 0.5;
    part.checkpoint_dir = Some(part_dir.clone());
    let part_losses = train(&part).unwrap().losses;

    let mut resumed = micro_cfg(tag, 12, 1);
    resumed.dropout = 0.5;
    resumed.checkpoint_dir = Some(part_dir.clone());
    resumed.resume = Some(ResumeFrom::Auto);
    let s = train(&resumed).unwrap();
    assert_eq!(s.resumed_from, Some(6));
    assert_eq!(s.losses.len(), 6, "resumed run executes only the remaining steps");

    // The two step-loss streams concatenate into the straight run's...
    let full: Vec<f32> = part_losses.iter().chain(&s.losses).copied().collect();
    assert_eq!(full, straight_losses, "loss stream must splice seamlessly");
    // ...and the final parameters + momenta are bit-identical.
    let a = load_final(&straight, &straight_dir);
    let b = load_final(&resumed, &part_dir);
    assert_eq!(a.max_divergence(&b), 0.0, "resume must be bit-exact");
}

/// Same criterion with 2 workers exchanging every step: resume goes
/// through the per-worker periodic snapshots `--resume auto` discovers.
#[test]
fn resume_is_bit_exact_two_workers_with_exchange() {
    let tag = "resume2";
    let straight_dir = ckpt_dir("straight2");
    let mut straight = micro_cfg(tag, 12, 2);
    straight.checkpoint_dir = Some(straight_dir.clone());
    let straight_losses = train(&straight).unwrap().losses;

    let part_dir = ckpt_dir("part2");
    let mut part = micro_cfg(tag, 6, 2);
    part.checkpoint_dir = Some(part_dir.clone());
    part.checkpoint_every = 3; // periodic per-worker sets at steps 3, 6
    train(&part).unwrap();
    assert!(part_dir.join(format!("{}_step3.w0.ckpt", part.name)).exists());
    assert!(part_dir.join(format!("{}_step6.w1.ckpt", part.name)).exists());
    assert!(part_dir.join("LATEST").exists());

    let mut resumed = micro_cfg(tag, 12, 2);
    resumed.checkpoint_dir = Some(part_dir.clone());
    resumed.checkpoint_every = 3;
    resumed.resume = Some(ResumeFrom::Auto);
    let s = train(&resumed).unwrap();
    assert_eq!(s.resumed_from, Some(6));
    let divergence = s.final_divergence.expect("2 workers report divergence");
    assert!(divergence < 1e-6, "replicas diverged after resume: {divergence}");
    // Worker-0 losses over steps 6..12 match the straight run exactly.
    assert_eq!(s.losses, &straight_losses[6..], "post-resume steps must replay bit-exactly");

    let a = load_final(&straight, &straight_dir);
    let b = load_final(&resumed, &part_dir);
    assert_eq!(a.max_divergence(&b), 0.0, "2-worker resume must be bit-exact");
}

/// The strongest form: exchange period 2 and a kill at an *odd* step,
/// where the replicas are legitimately desynchronized — only the
/// per-worker snapshots can restore each replica's private state.
#[test]
fn resume_is_bit_exact_when_replicas_are_desynchronized() {
    let tag = "resume3";
    let straight_dir = ckpt_dir("straight3");
    let mut straight = micro_cfg(tag, 10, 2);
    straight.exchange.period = 2;
    straight.checkpoint_dir = Some(straight_dir.clone());
    let straight_losses = train(&straight).unwrap().losses;

    let part_dir = ckpt_dir("part3");
    let mut part = micro_cfg(tag, 5, 2);
    part.exchange.period = 2;
    part.checkpoint_dir = Some(part_dir.clone());
    part.checkpoint_every = 5; // snapshot at step 5: no exchange ran there
    train(&part).unwrap();

    let mut resumed = micro_cfg(tag, 10, 2);
    resumed.exchange.period = 2;
    resumed.checkpoint_dir = Some(part_dir.clone());
    resumed.resume = Some(ResumeFrom::Auto);
    let s = train(&resumed).unwrap();
    assert_eq!(s.resumed_from, Some(5));
    assert_eq!(s.losses, &straight_losses[5..]);

    let a = load_final(&straight, &straight_dir);
    let b = load_final(&resumed, &part_dir);
    assert_eq!(a.max_divergence(&b), 0.0, "per-worker resume must restore private state");
}

/// Resuming with a changed resume-critical config must fail loudly,
/// not silently train something non-reproducible.
#[test]
fn resume_rejects_config_drift() {
    let tag = "resumedrift";
    let dir = ckpt_dir("drift");
    let mut part = micro_cfg(tag, 4, 1);
    part.checkpoint_dir = Some(dir.clone());
    train(&part).unwrap();
    let ckpt = dir.join(format!("{}_step4.ckpt", part.name));

    // Different seed => different data/augmentation stream.
    let mut resumed = micro_cfg(tag, 8, 1);
    resumed.seed = 8888;
    resumed.checkpoint_dir = Some(dir.clone());
    resumed.resume = Some(ResumeFrom::Path(ckpt.clone()));
    assert!(train(&resumed).is_err(), "seed drift must be rejected");

    // Steps lower than the checkpoint: nothing left to train.
    let mut resumed = micro_cfg(tag, 4, 1);
    resumed.checkpoint_dir = Some(dir.clone());
    resumed.resume = Some(ResumeFrom::Path(ckpt));
    assert!(train(&resumed).is_err(), "steps <= checkpoint step must be rejected");

    // Auto with an empty dir starts fresh instead of failing.
    let empty = ckpt_dir("driftempty");
    let mut fresh = micro_cfg(tag, 2, 1);
    fresh.checkpoint_dir = Some(empty);
    fresh.resume = Some(ResumeFrom::Auto);
    let s = train(&fresh).unwrap();
    assert_eq!(s.resumed_from, None);

    // Auto on an already-complete run is a no-op (supervisors re-run
    // the same command after success — that must not crash-loop): no
    // steps execute, the checkpoint is evaluated instead.
    let mut done_again = micro_cfg(tag, 4, 1);
    done_again.checkpoint_dir = Some(dir.clone());
    done_again.resume = Some(ResumeFrom::Auto);
    let s = train(&done_again).unwrap();
    assert_eq!(s.resumed_from, Some(4));
    assert!(s.losses.is_empty(), "no steps should re-train");
    assert_eq!(s.eval.expect("completed run still evaluates").examples, 64);
}

/// A resumed run splices its rows into the existing metrics CSV: the
/// pre-kill curve is kept, rows past the checkpoint (steps the resume
/// re-trains) are dropped, and nothing is duplicated.
#[test]
fn resumed_metrics_csv_has_no_duplicate_steps() {
    let tag = "resumecsv";
    let dir = ckpt_dir("resumecsv");
    let csv = std::env::temp_dir().join(format!("tmg_e2e_resumecsv_{}.csv", std::process::id()));
    let _ = std::fs::remove_file(&csv);
    let mut part = micro_cfg(tag, 8, 1);
    part.checkpoint_dir = Some(dir.clone());
    part.checkpoint_every = 6;
    part.metrics_csv = Some(csv.clone());
    train(&part).unwrap();

    // Resume from the step-6 snapshot: steps 6 and 7 ran past the
    // checkpoint (rows already logged) and get re-trained.
    let mut resumed = micro_cfg(tag, 12, 1);
    resumed.checkpoint_dir = Some(dir.clone());
    resumed.metrics_csv = Some(csv.clone());
    resumed.resume = Some(ResumeFrom::Path(dir.join(format!("{}_step6.w0.ckpt", part.name))));
    let s = train(&resumed).unwrap();
    assert_eq!(s.resumed_from, Some(6));

    let content = std::fs::read_to_string(&csv).unwrap();
    assert!(content.starts_with("step,worker,loss"), "header intact");
    let steps: Vec<&str> = content.lines().skip(1).map(|l| l.split(',').next().unwrap()).collect();
    assert_eq!(steps.len(), 12, "6 pre-kill rows + 6 resumed rows");
    let unique: std::collections::HashSet<_> = steps.iter().collect();
    assert_eq!(unique.len(), 12, "no duplicate step rows after resume");
}

/// Mid-training validation: `eval_every` produces the eval curve in the
/// summary and the sibling eval CSV, on top of the final eval.
#[test]
fn mid_training_validation_reports_and_csv() {
    let mut cfg = micro_cfg("evalmid", 6, 1);
    cfg.eval_every = 2;
    let csv = std::env::temp_dir().join(format!("tmg_e2e_evalmid_{}.csv", std::process::id()));
    cfg.metrics_csv = Some(csv.clone());
    let s = train(&cfg).unwrap();
    // Steps 2 and 4; the final step's eval is the summary's `eval`.
    assert_eq!(s.evals.len(), 2);
    assert_eq!((s.evals[0].step, s.evals[1].step), (2, 4));
    for r in &s.evals {
        assert_eq!(r.result.examples, 64, "mid-train eval must cover the full split");
        assert!(r.result.mean_loss.is_finite());
    }
    assert_eq!(s.eval.unwrap().examples, 64);
    let eval_csv = csv.with_extension("eval.csv");
    let content = std::fs::read_to_string(&eval_csv).unwrap();
    assert!(content.starts_with("step,examples,mean_loss,top1_error,top5_error"));
    assert_eq!(content.lines().count(), 1 + 2);
    // The step-metrics CSV is untouched by eval rows.
    let steps_csv = std::fs::read_to_string(&csv).unwrap();
    assert_eq!(steps_csv.lines().count(), 1 + 6);
}

/// Validation covers the whole split: a ragged tail (64 % 7 != 0) and
/// even a split smaller than one batch are evaluated, not dropped.
#[test]
fn validation_counts_every_example() {
    // Batch 7: 9 full batches + a tail of 1 example.
    let mut cfg = micro_cfg("ragged", 4, 1);
    cfg.batch_per_worker = 7;
    let s = train(&cfg).unwrap();
    assert_eq!(s.eval.unwrap().examples, 64, "ragged tail must be evaluated");

    // Batch larger than the split: the old trainer skipped eval
    // entirely; now the one partial batch is the whole measurement.
    let mut cfg = micro_cfg("ragged", 2, 1);
    cfg.batch_per_worker = 128;
    let s = train(&cfg).unwrap();
    assert_eq!(s.eval.expect("eval must run even when val < batch").examples, 64);
}

/// Tentpole invariant: streaming the bucketed gradient exchange from
/// inside backward must not change a single bit relative to the same
/// exchange run compute-then-exchange (`--overlap serial`), for any
/// worker count or intra-op thread count.  Gradient averaging at
/// period 1 also keeps the replicas bit-synchronized, so the strict
/// full-state divergence is exactly zero.
#[test]
fn overlap_stream_matches_serial_bitwise() {
    use theano_mgpu::config::OverlapMode;
    for workers in [2usize, 3] {
        let tag = format!("ovl{workers}");
        let mut reference: Option<(Vec<f32>, ParamStore)> = None;
        for (mode, threads) in [
            (OverlapMode::Serial, 1),
            (OverlapMode::Serial, 2),
            (OverlapMode::Stream, 1),
            (OverlapMode::Stream, 2),
        ] {
            let dir = ckpt_dir(&format!("ovl{workers}_{}_{threads}", mode.name()));
            let mut cfg = micro_cfg(&tag, 4, workers);
            cfg.exchange.overlap = mode;
            // Small buckets: several buckets per layer boundary, so the
            // watermark/push machinery is actually exercised.
            cfg.exchange.bucket_elems = 4096;
            cfg.compute_threads = threads;
            cfg.checkpoint_dir = Some(dir.clone());
            let s = train(&cfg).unwrap();
            assert_eq!(s.exchange_rounds, 4);
            assert!(s.collective.bucket_rounds > 0, "bucketed path must be active");
            assert_eq!(
                s.final_divergence.expect("replicas report divergence"),
                0.0,
                "gradient averaging must keep replicas bit-identical"
            );
            let store = load_final(&cfg, &dir);
            match &reference {
                None => reference = Some((s.losses, store)),
                Some((losses, want)) => {
                    assert_eq!(&s.losses, losses, "{mode:?} x{threads}t changed the losses");
                    assert_eq!(
                        want.max_divergence(&store),
                        0.0,
                        "{mode:?} x{threads}t changed the final state"
                    );
                }
            }
        }
    }
}

/// Streamed overlap reports where the comm time went: the bucket
/// counters and the overlapped/exposed split flow through the summary.
#[test]
fn overlap_stats_flow_into_the_summary() {
    use theano_mgpu::config::OverlapMode;
    let mut cfg = micro_cfg("ovlstats", 3, 2);
    cfg.exchange.overlap = OverlapMode::Stream;
    cfg.exchange.bucket_elems = 4096;
    let model = theano_mgpu::backend::resolve_model(&cfg).unwrap();
    let total: usize = model.params.iter().map(|p| p.shape.numel()).sum();
    let buckets = total.div_ceil(4096) as u64;
    assert!(buckets > 1, "test wants a multi-bucket layout, got {buckets}");
    let s = train(&cfg).unwrap();
    assert_eq!(s.collective.bucket_rounds, buckets * 3, "one bucket set per step");
    let comm = s.collective.overlapped_seconds + s.collective.exposed_seconds;
    assert!(comm > 0.0, "the bucket reductions must be timed");
}

/// `--resume auto` of an overlapped run must splice bit-exactly, like
/// the non-overlapped lifecycle tests above (the resume fingerprint
/// pins the exchange scheme and the bucket layout).
#[test]
fn overlap_resume_is_bit_exact() {
    use theano_mgpu::config::OverlapMode;
    let tag = "ovlresume";
    let overlap_cfg = |steps: usize, dir: &PathBuf| {
        let mut cfg = micro_cfg(tag, steps, 2);
        cfg.exchange.overlap = OverlapMode::Stream;
        cfg.exchange.bucket_elems = 4096;
        cfg.checkpoint_dir = Some(dir.clone());
        cfg
    };
    let straight_dir = ckpt_dir("ovlstraight");
    let straight = overlap_cfg(8, &straight_dir);
    let straight_losses = train(&straight).unwrap().losses;

    let part_dir = ckpt_dir("ovlpart");
    let mut part = overlap_cfg(4, &part_dir);
    part.checkpoint_every = 2; // per-worker snapshot sets at steps 2, 4
    train(&part).unwrap();

    let mut resumed = overlap_cfg(8, &part_dir);
    resumed.resume = Some(ResumeFrom::Auto);
    let s = train(&resumed).unwrap();
    assert_eq!(s.resumed_from, Some(4));
    assert_eq!(s.losses, &straight_losses[4..], "post-resume steps must replay bit-exactly");

    let a = load_final(&straight, &straight_dir);
    let b = load_final(&resumed, &part_dir);
    assert_eq!(a.max_divergence(&b), 0.0, "overlapped resume must be bit-exact");
}

#[test]
fn xla_backend_without_artifacts_falls_back_and_trains() {
    // The pre-refactor dead end: an artifact backend tag with no
    // artifacts on disk.  The factory now falls back to native and the
    // job completes.
    let mut cfg = micro_cfg("fallback", 3, 1);
    cfg.backend = "refconv".into();
    cfg.artifacts_dir = Path::new("/nonexistent/artifacts").to_path_buf();
    let s = train(&cfg).unwrap();
    assert_eq!(s.steps, 3);
    assert!(s.losses.iter().all(|l| l.is_finite()));
}
