//! Integration: the calibrated simulator reproduces the paper's shapes
//! end-to-end (E1, E3, E5) using canned calibration (hardware-free);
//! real calibration is exercised by `cargo bench`.

use theano_mgpu::sim::calibrate::CalibratedCosts;
use theano_mgpu::sim::pipeline::{simulate, PipelineParams};
use theano_mgpu::sim::scaling::scaling_study;
use theano_mgpu::sim::table1::{render, table1, Table1Options, PAPER_BACKENDS};

fn cells() -> Vec<theano_mgpu::sim::table1::Table1Cell> {
    table1(&Table1Options::with_costs(CalibratedCosts::canned())).unwrap()
}

fn pick(cells: &[theano_mgpu::sim::table1::Table1Cell], b: &str, g: usize, p: bool) -> f64 {
    cells
        .iter()
        .find(|c| c.backend == b && c.gpus == g && c.parallel_loading == p)
        .unwrap()
        .per20_s
}

#[test]
fn table1_matches_paper_factor_bands() {
    let cells = cells();
    // Paper: parallel loading saves 19-25% (1-GPU rows): 39.72->? etc.
    // We assert the saving is positive and below 60% (shape band).
    for b in PAPER_BACKENDS {
        for g in [1usize, 2] {
            let saving = 1.0 - pick(&cells, b, g, true) / pick(&cells, b, g, false);
            assert!(
                (0.02..0.6).contains(&saving),
                "{b}/{g}gpu: loading saving {saving}"
            );
        }
    }
    // Paper: 2-GPU speedups 1.66-1.70x (parallel loading rows).
    for b in PAPER_BACKENDS {
        let speedup = pick(&cells, b, 1, true) / pick(&cells, b, 2, true);
        assert!((1.3..2.0).contains(&speedup), "{b}: 2-GPU speedup {speedup}");
    }
    // Paper column order within a row: cudnn_r2 fastest.
    for g in [1usize, 2] {
        assert!(pick(&cells, "cudnn_r2", g, true) <= pick(&cells, "cudnn_r1", g, true));
        assert!(pick(&cells, "cudnn_r1", g, true) <= pick(&cells, "convnet", g, true));
    }
    // Headline: 2-GPU cudnn_r2 + parallel loading lands in the same
    // band as the caffe_cudnn comparator (paper: 19.72 vs 20.25).
    let ours = pick(&cells, "cudnn_r2", 2, true);
    let caffe = pick(&cells, "caffe_cudnn", 1, true);
    let ratio = ours / caffe;
    assert!((0.25..4.0).contains(&ratio), "headline ratio {ratio}");
}

#[test]
fn table1_renders_like_the_paper() {
    let s = render(&cells());
    assert!(s.contains("training time per 20 iterations"));
    for b in ["convnet", "cudnn_r1", "cudnn_r2", "caffe"] {
        assert!(s.contains(b), "missing column {b}");
    }
}

#[test]
fn overlap_saving_grows_with_load_fraction_until_loader_bound() {
    // E3 shape: the benefit of Fig-1 loading rises with load/compute
    // ratio, capping once the loader becomes the bottleneck.
    let mut prev_saving = -1.0;
    for ratio in [0.2, 0.5, 0.9] {
        let base = PipelineParams {
            workers: 1,
            compute_s: 1.0,
            load_s: ratio,
            exchange_s: 0.0,
            period: 1,
            parallel_loading: true,
            jitter: 0.0,
            seed: 1,
        };
        let par = simulate(&base, 100).mean_per20();
        let ser = simulate(&PipelineParams { parallel_loading: false, ..base }, 100).mean_per20();
        let saving = 1.0 - par / ser;
        assert!(saving > prev_saving, "saving not monotone at ratio {ratio}");
        prev_saving = saving;
    }
}

#[test]
fn scaling_study_shapes() {
    let rows = scaling_study(&CalibratedCosts::canned(), 60).unwrap();
    // Single-switch ring speedup must be monotone in N.
    let ring = |n: usize| {
        rows.iter()
            .find(|r| r.workers == n && r.topology == "single-switch" && (r.algorithm == "ring" || n == 1))
            .unwrap()
            .speedup
    };
    assert!(ring(2) > 1.3);
    assert!(ring(4) > ring(2));
    assert!(ring(8) > ring(4));
    // Dual-switch penalty exists at every N.
    for n in [2usize, 4, 8] {
        let single = rows
            .iter()
            .find(|r| r.workers == n && r.topology == "single-switch" && r.algorithm == "ring")
            .unwrap();
        let dual = rows
            .iter()
            .find(|r| r.workers == n && r.topology == "dual-switch" && r.algorithm == "ring")
            .unwrap();
        assert!(dual.speedup <= single.speedup + 1e-9);
    }
}

#[test]
fn exchange_period_ablation_shape() {
    // E6: larger periods amortize exchange cost -> lower s/20it.
    let mut prev = f64::INFINITY;
    for period in [1usize, 2, 4, 8] {
        let p = PipelineParams {
            workers: 2,
            compute_s: 1.0,
            load_s: 0.2,
            exchange_s: 0.3,
            period,
            parallel_loading: true,
            jitter: 0.0,
            seed: 2,
        };
        let t = simulate(&p, 80).mean_per20();
        assert!(t <= prev + 1e-9, "period {period}: {t} vs {prev}");
        prev = t;
    }
}
