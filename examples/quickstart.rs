//! Quickstart: load an AOT artifact, run one train step and one eval
//! step through the public API.  `cargo run --release --example quickstart`
//! (after `make artifacts`).

use theano_mgpu::params::ParamStore;
use theano_mgpu::runtime::literal_bridge::*;
use theano_mgpu::runtime::{Manifest, RuntimeClient};
use theano_mgpu::tensor::{HostTensor, Shape};
use theano_mgpu::util::Pcg32;

fn main() -> theano_mgpu::Result<()> {
    // 1. The manifest describes every compiled step and its ABI.
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let spec = manifest.artifact("train_alexnet-micro_cudnn_r2_b8")?;
    let model = manifest.model(&spec.model)?;
    println!(
        "artifact {} ({} backend, batch {}): {} inputs, {} outputs",
        spec.name,
        spec.backend,
        spec.batch_size,
        spec.inputs.len(),
        spec.outputs.len()
    );

    // 2. Compile it on the PJRT CPU client (the "virtual GPU").
    let client = RuntimeClient::cpu()?;
    let step = client.load_step(spec)?;

    // 3. Initialize parameters per the manifest (both replicas of a
    //    2-GPU job would call this with the same seed).
    let mut store = ParamStore::init(&model.params, 42);
    println!(
        "initialized {} tensors, {} parameters",
        store.n_tensors(),
        store.total_elements()
    );

    // 4. A synthetic minibatch (real training uses data::ParallelLoader).
    let b = spec.batch_size;
    let hw = model.image_hw;
    let mut rng = Pcg32::seeded(7);
    let mut images = HostTensor::zeros(Shape::of(&[b, model.in_channels, hw, hw]));
    rng.fill_normal(images.as_mut_slice(), 1.0);
    let labels: Vec<i32> = (0..b).map(|_| rng.below(model.num_classes as u32) as i32).collect();

    // 5. Run three steps and watch the loss move.
    for it in 0..3 {
        let mut inputs = vec![
            tensor_to_literal(&images)?,
            i32_to_literal(&labels)?,
            f32_scalar(0.05),
            i32_scalar(it),
        ];
        for p in &store.params {
            inputs.push(tensor_to_literal(p)?);
        }
        for m in &store.momenta {
            inputs.push(tensor_to_literal(m)?);
        }
        let outs = step.run(&inputs)?;
        let loss = literal_f32(&outs[0])?;
        let correct = literal_i32(&outs[1])?;
        println!("step {it}: loss {loss:.4}, {correct}/{b} correct");
        let n = store.n_tensors();
        let new_p = outs[2..2 + n]
            .iter()
            .zip(&store.specs)
            .map(|(l, s)| literal_to_tensor(l, s.shape.clone()).unwrap())
            .collect();
        let new_m = outs[2 + n..]
            .iter()
            .zip(&store.specs)
            .map(|(l, s)| literal_to_tensor(l, s.shape.clone()).unwrap())
            .collect();
        store.update_from(new_p, new_m)?;
    }
    println!("quickstart OK");
    Ok(())
}
