//! Quickstart: run real train + eval steps through the public API with
//! the native CPU backend — no AOT artifacts, no config files.
//!
//!     cargo run --release --example quickstart

use theano_mgpu::backend::{NativeBackend, StepBackend};
use theano_mgpu::params::ParamStore;
use theano_mgpu::sim::flops::alexnet_micro;
use theano_mgpu::tensor::{HostTensor, Shape};
use theano_mgpu::util::Pcg32;

fn main() -> theano_mgpu::Result<()> {
    // 1. Compile the architecture description into a step backend.
    //    (Swap in `alexnet_tiny()` or `alexnet()` for bigger runs, or
    //    build from a config with `backend::build_backend`.)
    let arch = alexnet_micro();
    let mut backend = NativeBackend::new(&arch, 0.5);
    let model = backend.model().clone();
    println!(
        "model {}: {}x{}x{} input, {} classes, {} param tensors",
        model.name,
        model.in_channels,
        model.image_hw,
        model.image_hw,
        model.num_classes,
        model.params.len()
    );

    // 2. Initialize parameters per the derived manifest (both replicas
    //    of a 2-GPU job would call this with the same seed).
    let mut store = ParamStore::init(&model.params, 42);
    println!(
        "initialized {} tensors, {} parameters",
        store.n_tensors(),
        store.total_elements()
    );

    // 3. A synthetic minibatch (real training uses data::ParallelLoader).
    let b = 8usize;
    let hw = model.image_hw;
    let mut rng = Pcg32::seeded(7);
    let images = HostTensor::rand_normal(Shape::of(&[b, model.in_channels, hw, hw]), &mut rng, 1.0);
    let labels: Vec<i32> = (0..b).map(|_| rng.below(model.num_classes as u32) as i32).collect();

    // 4. Run three SGD-momentum steps and watch the loss move.
    for it in 0..3 {
        let out = backend.train_step(&images, &labels, 0.05, it, &mut store)?;
        println!("step {it}: loss {:.4}, {}/{b} correct", out.loss, out.correct1);
    }

    // 5. An eval forward pass (dropout off, top-1/top-5 counts).
    let e = backend.eval_batch(&images, &labels, &store)?;
    println!(
        "eval on the same batch: loss {:.4}, top-1 {}/{b}, top-5 {}/{b}",
        e.loss, e.top1, e.top5
    );
    println!("quickstart OK");
    Ok(())
}
