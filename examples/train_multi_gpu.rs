//! **The end-to-end driver (E2).**  Trains AlexNet-tiny on the
//! synthetic ImageNet substitute with the paper's full 2-GPU recipe —
//! parallel loading (Fig 1) + per-step exchange-and-average of weights
//! and momenta (Fig 2) — then evaluates top-1/top-5 validation error
//! and writes the loss curve to CSV.
//!
//! Also runs the 1-worker large-batch control (B=32 vs 2xB=16), the
//! comparison behind the paper's "2 GPUs, half the batch each" claim.
//!
//!     cargo run --release --example train_multi_gpu [steps]
//!
//! Defaults to 300 steps; results land in EXPERIMENTS.md §E2.

use std::path::PathBuf;

use theano_mgpu::config::{ClusterConfig, DataConfig, TrainConfig};
use theano_mgpu::coordinator::trainer::{train, TrainSummary};
use theano_mgpu::data::synth::{generate_dataset, SynthSpec};

fn base_cfg(steps: usize, data_dir: PathBuf) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = "alexnet-tiny".into();
    // Native CPU backend: runs everywhere, no AOT artifacts needed.
    cfg.backend = "native".into();
    cfg.steps = steps;
    cfg.log_every = 20;
    cfg.seed = 17;
    cfg.schedule.base_lr = 0.02;
    cfg.schedule.decay_factor = 0.1;
    cfg.schedule.milestones = vec![steps * 2 / 3];
    cfg.data = DataConfig {
        dir: data_dir,
        train_examples: 8192,
        val_examples: 512,
        shard_examples: 2048,
        seed: 1234,
        stored_hw: 72,
    };
    cfg
}

fn report(tag: &str, s: &TrainSummary) {
    let first = s.losses.first().copied().unwrap_or(0.0);
    let last10: Vec<f32> = s.losses.iter().rev().take(10).copied().collect();
    let final_loss = last10.iter().sum::<f32>() / last10.len().max(1) as f32;
    println!("--- {tag} ---");
    println!(
        "  steps {}  workers {}  wall {:.1}s  {:.2} s/20it",
        s.steps, s.workers, s.wall_seconds, s.secs_per_20_iters
    );
    println!("  loss {first:.3} -> {final_loss:.3}");
    let divergence = match s.final_divergence {
        Some(d) => format!("{d:.2e}"),
        None => "n/a (single worker)".into(),
    };
    println!(
        "  compute {:.1}s/worker, exchange {:.1}s ({} rounds), divergence {divergence}",
        s.compute_seconds, s.exchange_seconds, s.exchange_rounds
    );
    println!(
        "  collective phases/worker: flatten {:.2}s, transfer {:.2}s, average {:.2}s",
        s.collective.flatten_seconds, s.collective.transfer_seconds, s.collective.average_seconds
    );
    for (w, l) in s.loader.iter().enumerate() {
        println!(
            "  loader[{w}]: load {:.2}s, stall {:.2}s (hidden: {:.0}%)",
            l.load_seconds,
            l.stall_seconds,
            100.0 * (1.0 - l.stall_seconds / l.load_seconds.max(1e-9))
        );
    }
    if let Some(e) = s.eval {
        println!(
            "  validation: top-1 error {:.1}%  top-5 error {:.1}%  ({} examples)",
            100.0 * e.top1_error(),
            100.0 * e.top5_error(),
            e.examples
        );
    }
}

fn main() -> theano_mgpu::Result<()> {
    theano_mgpu::cli::init_logging();
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let data_dir = PathBuf::from("data/tiny_e2e");
    if !data_dir.join("meta.json").exists() {
        println!("generating synthetic ImageNet substitute (8192 train / 512 val, 100 classes)...");
        let spec = SynthSpec { classes: 100, hw: 72, seed: 1234, ..Default::default() };
        generate_dataset(&data_dir, &spec, 8192, 512, 2048)?;
    }

    // --- The paper's configuration: 2 replicas x B=16, Fig-1 + Fig-2. ---
    let mut two = base_cfg(steps, data_dir.clone());
    two.name = "tiny-2gpu".into();
    two.batch_per_worker = 16;
    two.cluster = ClusterConfig::pair_same_switch();
    two.metrics_csv = Some(PathBuf::from("target/e2e_2gpu_loss.csv"));
    println!("\n=== 2-worker data parallelism (2 x B=16, exchange every step) ===");
    let s2 = train(&two)?;
    report("2-worker", &s2);

    // --- Control: single worker at the combined batch (B=32). ---
    let mut one = base_cfg(steps, data_dir);
    one.name = "tiny-1gpu".into();
    one.batch_per_worker = 32;
    one.cluster = ClusterConfig::single();
    one.metrics_csv = Some(PathBuf::from("target/e2e_1gpu_loss.csv"));
    println!("\n=== 1-worker control (B=32) ===");
    let s1 = train(&one)?;
    report("1-worker", &s1);

    // --- The paper's accuracy-shape claim: the averaged 2-replica run
    //     tracks the large-batch run. ---
    let tail = |s: &TrainSummary| {
        let t: Vec<f32> = s.losses.iter().rev().take(20).copied().collect();
        t.iter().sum::<f32>() / t.len().max(1) as f32
    };
    let (l2, l1) = (tail(&s2), tail(&s1));
    println!("\nfinal-loss comparison: 2-worker {l2:.3} vs 1-worker {l1:.3}");
    if (l2 - l1).abs() < 0.35 * l1.abs().max(0.2) {
        println!("-> within band: replica averaging tracks large-batch SGD (paper §3)");
    } else {
        println!("-> WARNING: runs diverge more than expected");
    }
    println!("\nloss curves: target/e2e_2gpu_loss.csv, target/e2e_1gpu_loss.csv");
    Ok(())
}
