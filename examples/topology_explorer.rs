//! §4.4 demo: how PCIe topology dictates the exchange transport and
//! its cost, including the paper's own 3-GPU testbed.
//!
//!     cargo run --release --example topology_explorer

use theano_mgpu::comm::cost::CommCostModel;
use theano_mgpu::interconnect::routing::{exchange_time, route};
use theano_mgpu::interconnect::topology::{PcieTopology, TopologyBuilder};
use theano_mgpu::sim::flops::alexnet;
use theano_mgpu::util::fmt;

fn explore(name: &str, topo: &PcieTopology) -> theano_mgpu::Result<()> {
    let model = CommCostModel::default();
    let bytes = alexnet().exchange_bytes() as usize;
    println!("\n== {name} ({} devices, {} switches) ==", topo.devices(), topo.switches);
    println!("   exchange payload: {} (AlexNet params+momenta)", fmt::bytes(bytes));
    for a in 0..topo.devices() {
        for b_dev in (a + 1)..topo.devices() {
            let r = route(topo, a, b_dev)?;
            let t = exchange_time(topo, &model, a, b_dev, bytes)?;
            println!(
                "   GPU{a} <-> GPU{b_dev}: {:<11} ({} hops)  Fig-2 round = {}",
                r.transport.name(),
                r.hops,
                fmt::secs(t)
            );
        }
    }
    Ok(())
}

fn main() -> theano_mgpu::Result<()> {
    // The paper's machine: 2 Titan Blacks under one switch (used for
    // the 2-GPU runs) + 1 under another (left idle — §3 explains why:
    // no P2P across the root complex).
    explore("paper testbed", &PcieTopology::paper_testbed())?;

    // An 8-GPU single-switch box: everything P2P.
    explore(
        "8-GPU single switch",
        &TopologyBuilder::new().switch_with(8).build()?,
    )?;

    // An 8-GPU dual-switch box: the diagonal pays the host path.
    explore(
        "8-GPU dual switch (4+4)",
        &TopologyBuilder::new().switch_with(4).switch_with(4).build()?,
    )?;

    println!(
        "\nThe same-switch P2P rule is why the paper used GPUs 0 and 1 and left \
         the third idle — and why `coordinator` downgrades the transport \
         automatically when a config places workers across switches."
    );
    Ok(())
}
