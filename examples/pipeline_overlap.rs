//! Fig-1 demo: watch the parallel loading pipeline hide the data cost.
//!
//! Runs the same micro-model training twice — serial loading vs the
//! Fig-1 prefetching loader — and prints per-window step times plus the
//! loader's own accounting (load seconds vs trainer stall seconds).
//!
//!     cargo run --release --example pipeline_overlap

use std::path::PathBuf;

use theano_mgpu::config::{ClusterConfig, DataConfig, LoaderMode, TrainConfig};
use theano_mgpu::coordinator::trainer::train;
use theano_mgpu::data::synth::{generate_dataset, SynthSpec};

fn main() -> theano_mgpu::Result<()> {
    theano_mgpu::cli::init_logging();
    let data_dir = PathBuf::from("data/overlap_demo");
    if !data_dir.join("meta.json").exists() {
        // Large stored images (96px) make loading expensive enough to
        // matter against the micro model's small compute.
        let spec = SynthSpec { classes: 10, hw: 96, seed: 5, ..Default::default() };
        generate_dataset(&data_dir, &spec, 2048, 128, 512)?;
    }

    let mut cfg = TrainConfig::default();
    cfg.model = "alexnet-micro".into();
    cfg.backend = "native".into();
    cfg.batch_per_worker = 8;
    cfg.steps = 60;
    cfg.log_every = 0;
    cfg.seed = 3;
    cfg.schedule.base_lr = 0.01;
    cfg.cluster = ClusterConfig::single();
    cfg.data = DataConfig {
        dir: data_dir,
        train_examples: 2048,
        val_examples: 128,
        shard_examples: 512,
        seed: 5,
        stored_hw: 96,
    };
    // Micro model crops to 32 from 96 stored pixels.

    let mut results = Vec::new();
    for mode in [LoaderMode::Serial, LoaderMode::Parallel] {
        cfg.loader_mode = mode;
        let s = train(&cfg)?;
        let loader = s.loader[0];
        println!("\n=== {mode:?} loading ===");
        println!("  wall time          : {:.2}s for {} steps", s.wall_seconds, s.steps);
        println!("  mean s/20 iters    : {:.3}", s.secs_per_20_iters);
        println!("  loader load time   : {:.2}s total", loader.load_seconds);
        println!(
            "  trainer stall      : {:.2}s total ({:.0}% of load hidden)",
            loader.stall_seconds,
            100.0 * (1.0 - loader.stall_seconds / loader.load_seconds.max(1e-9))
        );
        results.push((mode, s.wall_seconds, s.losses));
    }

    let (m0, t0, l0) = &results[0];
    let (m1, t1, l1) = &results[1];
    println!("\n{m1:?} vs {m0:?}: {:.1}% faster", 100.0 * (1.0 - t1 / t0));
    assert_eq!(l0, l1, "loading mode must not change the math (Fig 1 is pure schedule)");
    println!("loss curves identical across modes — the pipeline is semantically transparent.");
    Ok(())
}
