"""L2: the AOT-lowered train/eval step functions (the "Theano function").

The paper compiled one Theano function per GPU that consumed a staged
minibatch and updated device-resident weights + momenta in place.  The
equivalent here is a pure function over explicit state:

  train_step(images, labels, lr, seed, *params, *momenta)
    -> (loss, correct1, *new_params, *new_momenta)

  eval_step(images, labels, *params) -> (loss, correct1, correct5)

Update rule (paper §2 / Krizhevsky et al. 2012):
  v <- mu * v - lr * (grad + wd * w);   w <- w + v
with mu = 0.9, wd = 5e-4.

The Fig-2 exchange averages *params and momenta* on the Rust side, so
both are step outputs; everything stays device-resident between steps
(``execute_b`` over PjRtBuffers in rust/src/runtime/).
"""

from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.ref import softmax_xent_ref
from .model import ModelConfig, forward

MOMENTUM = 0.9
WEIGHT_DECAY = 5e-4


def loss_fn(
    cfg: ModelConfig,
    params: List[jax.Array],
    images: jax.Array,
    labels: jax.Array,
    *,
    backend: str,
    train: bool,
    dropout_key=None,
) -> Tuple[jax.Array, jax.Array]:
    logits = forward(
        cfg, params, images, backend=backend, train=train, dropout_key=dropout_key
    )
    loss = softmax_xent_ref(logits, labels)
    return loss, logits


def _topk_correct(logits, labels, k):
    """Top-k correctness via rank counting.

    Deliberately avoids ``lax.top_k``: jax >= 0.6 lowers it to a
    ``topk(..., largest=true)`` HLO attribute that xla_extension 0.5.1's
    text parser rejects.  An example is top-k correct iff fewer than k
    logits strictly exceed the gold logit — plain compare+reduce HLO.
    (Equivalent to top_k membership up to ties; verified against the
    real top_k in python/tests.)
    """
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)
    rank = jnp.sum((logits > gold).astype(jnp.int32), axis=-1)
    return jnp.sum((rank < k).astype(jnp.int32))


def make_train_step(cfg: ModelConfig, backend: str, n_params: int):
    """Build the flat-signature train step for (cfg, backend)."""

    def train_step(images, labels, lr, seed, *state):
        assert len(state) == 2 * n_params, (len(state), n_params)
        params = list(state[:n_params])
        momenta = list(state[n_params:])
        dropout_key = jax.random.key(seed) if cfg.dropout > 0.0 else None

        def scalar_loss(ps):
            return loss_fn(
                cfg,
                ps,
                images,
                labels,
                backend=backend,
                train=True,
                dropout_key=dropout_key,
            )

        # Single fwd+bwd; correct1 reuses the training logits (dropout
        # noise in the running accuracy is acceptable — a second
        # eval-mode fwd would double the step cost).
        (loss, logits), grads = jax.value_and_grad(scalar_loss, has_aux=True)(params)
        correct1 = _topk_correct(logits, labels, 1)

        new_params, new_momenta = [], []
        for w, v, g in zip(params, momenta, grads):
            v_new = MOMENTUM * v - lr * (g + WEIGHT_DECAY * w)
            new_params.append(w + v_new)
            new_momenta.append(v_new)
        return (loss, correct1, *new_params, *new_momenta)

    return train_step


def make_eval_step(cfg: ModelConfig, backend: str, n_params: int):
    """Build the flat-signature eval step for (cfg, backend)."""

    def eval_step(images, labels, *params):
        assert len(params) == n_params
        loss, logits = loss_fn(
            cfg, list(params), images, labels, backend=backend, train=False
        )
        correct1 = _topk_correct(logits, labels, 1)
        correct5 = _topk_correct(logits, labels, min(5, cfg.num_classes))
        return loss, correct1, correct5

    return eval_step
