"""L1 Pallas kernels + pure-jnp oracles.

Public surface used by the L2 model:

- ``conv.conv2d`` / ``conv.conv2d_bias_relu`` / ``conv.linear`` /
  ``conv.linear_bias_relu`` — backend-dispatched conv/FC.
- ``maxpool.maxpool`` — overlapping max pool.
- ``lrn.lrn`` — AlexNet local response normalization.
- ``ref`` — oracles for all of the above (pytest ground truth).
"""

from . import bias_relu, conv, lrn, matmul_pallas, maxpool, ref  # noqa: F401

BACKENDS = conv.BACKENDS
