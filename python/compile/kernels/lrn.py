"""Pallas local response normalization (AlexNet §3.3, across channels).

Forward is a Pallas kernel over one image per grid step: the channel
window sum is a static unroll over the 2r+1 shifted channel slices of a
zero-padded square tensor.  Backward differentiates the reference
implementation at the saved input (same numerics, XLA-generated).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_INTERPRET = True


def _lrn_kernel(x_ref, o_ref, *, radius, bias, alpha, beta, channels):
    x = x_ref[...]  # [1, C, H, W]
    sq = (x * x).astype(jnp.float32)
    n = 2 * radius + 1
    pad = jnp.pad(sq, ((0, 0), (radius, radius), (0, 0), (0, 0)))
    acc = pad[:, 0:channels]
    for d in range(1, n):
        acc = acc + pad[:, d : d + channels]
    scale = (bias + (alpha / n) * acc) ** beta
    o_ref[...] = (x / scale).astype(o_ref.dtype)


def _lrn_raw(x, radius, bias, alpha, beta):
    n, c, h, w = x.shape
    kern = partial(
        _lrn_kernel, radius=radius, bias=bias, alpha=alpha, beta=beta, channels=c
    )
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=_INTERPRET,
    )(x)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def lrn(x, depth_radius=2, bias=2.0, alpha=1e-4, beta=0.75):
    """AlexNet cross-channel LRN; defaults match Krizhevsky et al. 2012."""
    return _lrn_raw(x, depth_radius, bias, alpha, beta)


def _lrn_fwd(x, depth_radius, bias, alpha, beta):
    return _lrn_raw(x, depth_radius, bias, alpha, beta), x


def _lrn_bwd(depth_radius, bias, alpha, beta, x, g):
    _, vjp = jax.vjp(lambda t: ref.lrn_ref(t, depth_radius, bias, alpha, beta), x)
    return (vjp(g)[0],)


lrn.defvjp(_lrn_fwd, _lrn_bwd)
