"""Convolution backends built on the Pallas GEMM schedules.

Convolution is lowered as im2col + GEMM: patch extraction is a pure
data-movement op (differentiable through JAX — its transpose is the
col2im scatter XLA already implements), and *all* FLOPs flow through the
Pallas ``matmul`` kernels, fwd and bwd.  The backend name selects the
GEMM schedule per DESIGN.md §Hardware-Adaptation:

  refconv   -> XLA lax.conv (the "Caffe" comparator; no Pallas)
  convnet   -> naive full-K panels   (cuda-convnet analog)
  cudnn_r1  -> output-stationary K-tiled (cuDNN R1 analog)
  cudnn_r2  -> K-tiled + wide-N + fused bias+ReLU epilogue (cuDNN R2)
"""

import jax.numpy as jnp
from jax import lax

from . import ref
from .matmul_pallas import matmul, matmul_bias_relu_fused

BACKENDS = ("refconv", "convnet", "cudnn_r1", "cudnn_r2")


def _im2col(x, kh, kw, stride, padding):
    """[N,C,H,W] -> ([N*Ho*Wo, C*Kh*Kw], Ho, Wo). Differentiable."""
    n, _, h, w = x.shape
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (w + 2 * padding - kw) // stride + 1
    patches = lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # [N, C*Kh*Kw, Ho, Wo]
    ckk = patches.shape[1]
    cols = jnp.moveaxis(patches, 1, -1).reshape(n * ho * wo, ckk)
    return cols, ho, wo


def conv2d(x, w, *, stride=1, padding=0, backend="cudnn_r1"):
    """NCHW conv: x [N,Cin,H,W], w [Cout,Cin,Kh,Kw] -> [N,Cout,Ho,Wo]."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown conv backend {backend!r}; want one of {BACKENDS}")
    if backend == "refconv":
        return ref.conv2d_ref(x, w, stride=stride, padding=padding)
    n = x.shape[0]
    cout, _, kh, kw = w.shape
    cols, ho, wo = _im2col(x, kh, kw, stride, padding)
    wmat = w.reshape(cout, -1).T  # [Cin*Kh*Kw, Cout]
    y = matmul(cols, wmat, backend)  # [N*Ho*Wo, Cout]
    return jnp.moveaxis(y.reshape(n, ho, wo, cout), -1, 1)


def conv2d_bias_relu(x, w, b, *, stride=1, padding=0, backend="cudnn_r1"):
    """conv + bias + ReLU; on cudnn_r2 the epilogue is fused into the GEMM."""
    if backend == "cudnn_r2":
        n = x.shape[0]
        cout, _, kh, kw = w.shape
        cols, ho, wo = _im2col(x, kh, kw, stride, padding)
        wmat = w.reshape(cout, -1).T
        y = matmul_bias_relu_fused(cols, wmat, b)
        return jnp.moveaxis(y.reshape(n, ho, wo, cout), -1, 1)
    y = conv2d(x, w, stride=stride, padding=padding, backend=backend)
    return jnp.maximum(y + b[None, :, None, None], 0.0)


def linear(x, w, *, backend="cudnn_r1"):
    """Fully-connected layer through the same GEMM engine. x [B,D], w [D,K]."""
    if backend == "refconv":
        return ref.matmul_ref(x, w)
    return matmul(x, w, backend)


def linear_bias_relu(x, w, b, *, backend="cudnn_r1"):
    """FC + bias + ReLU; fused epilogue on cudnn_r2."""
    if backend == "cudnn_r2":
        return matmul_bias_relu_fused(x, w, b)
    return jnp.maximum(linear(x, w, backend=backend) + b[None, :], 0.0)
