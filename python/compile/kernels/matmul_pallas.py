"""Tiled GEMM Pallas kernels — the compute hot-spot of every conv backend.

The paper timed three GPU convolution backends (cuda-convnet, cuDNN-R1,
cuDNN-R2).  On this stack convolution lowers to im2col + GEMM (see
``conv.py``), so the backend differences become *GEMM schedule*
differences, exactly as they were threadblock-tiling differences on GPU
(DESIGN.md §Hardware-Adaptation):

- ``convnet``  — naive schedule: 2-D grid, each program reads a full
  [bm, K] row-panel and [K, bn] col-panel (no K tiling).  Large VMEM
  blocks, lowest arithmetic-intensity-per-byte-staged; the cuda-convnet
  analog.
- ``cudnn_r1`` — output-stationary: 3-D grid with K innermost, f32
  accumulation into the revisited output block.  The implicit-GEMM
  cuDNN-R1 analog.
- ``cudnn_r2`` — like r1 but with a wider N block (fewer grid trips)
  and an optional fused bias+ReLU epilogue on the last K step, the
  cuDNN-R2 "fused ops" analog.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); TPU viability is asserted structurally via the VMEM
budget check in ``vmem_block_bytes`` and the pytest suite.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shapes per schedule.  (bm, bn, bk); bk=None means "full K".
# 128 is the MXU-native tile edge; r2 widens N to 256 to halve grid trips.
SCHEDULES = {
    "convnet": dict(bm=128, bn=128, bk=None),
    "cudnn_r1": dict(bm=128, bn=128, bk=128),
    "cudnn_r2": dict(bm=128, bn=256, bk=128),
}

_INTERPRET = True  # CPU PJRT: Mosaic custom-calls are not executable.


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def vmem_block_bytes(m: int, n: int, k: int, schedule: str, dtype=jnp.float32) -> int:
    """Estimated VMEM bytes resident per grid step for a schedule.

    Used by the pytest structural checks and by DESIGN.md §Perf to argue
    TPU viability: blocks must fit the ~16 MiB VMEM budget.
    """
    cfg = SCHEDULES[schedule]
    bm, bn = cfg["bm"], cfg["bn"]
    bk = cfg["bk"] if cfg["bk"] is not None else _ceil_to(k, 128)
    esize = jnp.dtype(dtype).itemsize
    # A block + B block + output accumulator (f32).
    return bm * bk * esize + bk * bn * esize + bm * bn * 4


def mxu_utilization_estimate(m: int, n: int, k: int, schedule: str) -> float:
    """Fraction of MXU-issue slots doing useful work (padding overhead).

    The MXU consumes 128x128 tiles; padded rows/cols are wasted issue
    slots.  This is the structural utilization estimate recorded in
    EXPERIMENTS.md §Perf (interpret mode gives no real TPU timing).
    """
    cfg = SCHEDULES[schedule]
    bm, bn = cfg["bm"], cfg["bn"]
    bk = cfg["bk"] if cfg["bk"] is not None else _ceil_to(k, 128)
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    useful = m * n * k
    issued = mp * np_ * kp
    return useful / issued


def _mm_naive_kernel(a_ref, b_ref, o_ref):
    """convnet schedule: full-K panels, one shot per output block."""
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _mm_ktiled_kernel(a_ref, b_ref, o_ref, *, nk: int, epilogue: bool, bias_ref=None):
    """cudnn_r1/r2 schedule: output-stationary accumulation over K steps.

    The output block is revisited across the innermost grid dimension;
    f32 accumulation happens in the output ref (interpret mode executes
    the grid sequentially, matching TPU's arbitrary-dimension semantics).
    """
    kstep = pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    if epilogue:

        @pl.when(kstep == nk - 1)
        def _epilogue():
            acc = o_ref[...] + bias_ref[...]
            o_ref[...] = jnp.maximum(acc, jnp.zeros_like(acc))


def _pad2(x, m0, m1):
    p0, p1 = m0 - x.shape[0], m1 - x.shape[1]
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)))


def _matmul_pallas_raw(a, b, schedule: str, bias=None, fuse_bias_relu=False):
    """Dispatch one GEMM through the requested Pallas schedule.

    a: [M, K]; b: [K, N]; bias: [N] (only with ``fuse_bias_relu``).
    Inputs are zero-padded to block multiples and the result sliced back.
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"matmul_pallas expects 2-D operands, got {a.shape} @ {b.shape}")
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    if fuse_bias_relu and schedule != "cudnn_r2":
        raise ValueError("fused bias+relu epilogue is the cudnn_r2 schedule only")

    m, k = a.shape
    _, n = b.shape
    cfg = SCHEDULES[schedule]
    bm = min(cfg["bm"], _ceil_to(m, 8))
    bn = min(cfg["bn"], _ceil_to(n, 8))
    bk_cfg = cfg["bk"]
    # Accumulate in f32 regardless of operand dtype (MXU-style), cast at
    # the end — keeps the K-tiled += accumulation exact for bf16 inputs.
    out_dtype = jnp.float32

    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)

    if bk_cfg is None:
        # convnet: no K tiling — panels span the whole contraction dim.
        kp = max(k, 1)
        ap = _pad2(a, mp, kp)
        bp = _pad2(b, kp, np_)
        out = pl.pallas_call(
            _mm_naive_kernel,
            grid=(mp // bm, np_ // bn),
            in_specs=[
                pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
                pl.BlockSpec((kp, bn), lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
            interpret=_INTERPRET,
        )(ap, bp)
    else:
        bk = min(bk_cfg, _ceil_to(k, 8))
        kp = _ceil_to(k, bk)
        ap = _pad2(a, mp, kp)
        bp = _pad2(b, kp, np_)
        nk = kp // bk
        kern = partial(
            _mm_ktiled_kernel,
            nk=nk,
            epilogue=fuse_bias_relu,
        )
        in_specs = [
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ]
        operands = [ap, bp]
        if fuse_bias_relu:
            bias_p = jnp.pad(bias, (0, np_ - n)).reshape(1, np_)

            def kern(a_ref, b_ref, bias_ref, o_ref, nk=nk):  # noqa: F811
                _mm_ktiled_kernel(
                    a_ref, b_ref, o_ref, nk=nk, epilogue=True, bias_ref=bias_ref
                )

            in_specs.append(pl.BlockSpec((1, bn), lambda i, j, s: (0, j)))
            operands.append(bias_p)
        out = pl.pallas_call(
            kern,
            grid=(mp // bm, np_ // bn, nk),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
            interpret=_INTERPRET,
        )(*operands)

    return out[:m, :n].astype(a.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def matmul(a, b, schedule="cudnn_r1"):
    """Differentiable Pallas GEMM; bwd also runs through Pallas GEMMs."""
    return _matmul_pallas_raw(a, b, schedule)


def _matmul_fwd(a, b, schedule):
    return _matmul_pallas_raw(a, b, schedule), (a, b)


def _matmul_bwd(schedule, res, g):
    a, b = res
    # dA = g @ B^T, dB = A^T @ g — the same schedule serves the bwd GEMMs,
    # mirroring how cuDNN's bwd-data/bwd-filter reuse its GEMM engine.
    da = _matmul_pallas_raw(g, b.T, schedule)
    db = _matmul_pallas_raw(a.T, g, schedule)
    return da.astype(a.dtype), db.astype(b.dtype)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


@partial(jax.custom_vjp, nondiff_argnums=())
def matmul_bias_relu_fused(a, b, bias):
    """cudnn_r2's fused GEMM+bias+ReLU epilogue (fwd hot path)."""
    return _matmul_pallas_raw(a, b, "cudnn_r2", bias=bias, fuse_bias_relu=True)


def _mmbr_fwd(a, b, bias):
    y = _matmul_pallas_raw(a, b, "cudnn_r2", bias=bias, fuse_bias_relu=True)
    return y, (a, b, y)


def _mmbr_bwd(res, g):
    a, b, y = res
    # ReLU mask from the saved output (y > 0 iff pre-activation > 0).
    g = g * (y > 0).astype(g.dtype)
    da = _matmul_pallas_raw(g, b.T, "cudnn_r2")
    db = _matmul_pallas_raw(a.T, g, "cudnn_r2")
    dbias = jnp.sum(g, axis=0)
    return da.astype(a.dtype), db.astype(b.dtype), dbias.astype(g.dtype)


matmul_bias_relu_fused.defvjp(_mmbr_fwd, _mmbr_bwd)
