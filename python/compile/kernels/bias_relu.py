"""Standalone fused bias+ReLU Pallas kernel.

Used by the ``convnet`` and ``cudnn_r1`` backends, whose GEMM schedules
do not fuse the epilogue (``cudnn_r2`` fuses it into the GEMM itself —
see matmul_pallas.matmul_bias_relu_fused).  Row-blocked elementwise
kernel with an analytic VJP.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INTERPRET = True
_BLOCK_ROWS = 256


def _bias_relu_kernel(x_ref, b_ref, o_ref):
    v = x_ref[...] + b_ref[...]
    o_ref[...] = jnp.maximum(v, jnp.zeros_like(v))


def _bias_relu_raw(x, b):
    m, n = x.shape
    bm = min(_BLOCK_ROWS, m)
    mp = (m + bm - 1) // bm * bm
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    out = pl.pallas_call(
        _bias_relu_kernel,
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), x.dtype),
        interpret=_INTERPRET,
    )(xp, b.reshape(1, n))
    return out[:m]


@jax.custom_vjp
def bias_relu(x, b):
    """max(x + b, 0) with bias broadcast over rows. x [M,N], b [N]."""
    return _bias_relu_raw(x, b)


def _br_fwd(x, b):
    y = _bias_relu_raw(x, b)
    return y, y


def _br_bwd(y, g):
    g = g * (y > 0).astype(g.dtype)
    return g, jnp.sum(g, axis=0)


bias_relu.defvjp(_br_fwd, _br_bwd)
