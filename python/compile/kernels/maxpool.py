"""Pallas max-pooling kernel (AlexNet's overlapping 3x3/2 pooling).

Forward is a Pallas kernel that walks the window positions with static
slices inside one block (the whole [C,H,W] plane of one image per grid
step — AlexNet planes are far under the VMEM budget).  Backward routes
through the XLA reduce-window gradient of the reference implementation
so tie-breaking semantics exactly match the oracle.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_INTERPRET = True


def _pool_kernel(x_ref, o_ref, *, window: int, stride: int, ho: int, wo: int):
    x = x_ref[...]  # [1, C, H, W] block: one image per grid step
    parts = []
    # Static unroll over the window offsets: each (dy, dx) contributes a
    # strided slice; the running max across offsets is the pooled output.
    for dy in range(window):
        for dx in range(window):
            sl = jax.lax.slice(
                x,
                (0, 0, dy, dx),
                (x.shape[0], x.shape[1], dy + (ho - 1) * stride + 1, dx + (wo - 1) * stride + 1),
                (1, 1, stride, stride),
            )
            parts.append(sl)
    acc = parts[0]
    for p in parts[1:]:
        acc = jnp.maximum(acc, p)
    o_ref[...] = acc.astype(o_ref.dtype)


def _maxpool_raw(x, window, stride):
    n, c, h, w = x.shape
    ho = (h - window) // stride + 1
    wo = (w - window) // stride + 1
    kern = partial(_pool_kernel, window=window, stride=stride, ho=ho, wo=wo)
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, c, ho, wo), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, ho, wo), x.dtype),
        interpret=_INTERPRET,
    )(x)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def maxpool(x, window=3, stride=2):
    """Overlapping max pool, NCHW, VALID padding (AlexNet: 3x3 stride 2)."""
    return _maxpool_raw(x, window, stride)


def _maxpool_fwd(x, window, stride):
    return _maxpool_raw(x, window, stride), x


def _maxpool_bwd(window, stride, x, g):
    # Gradient of the oracle at the saved input: identical tie semantics.
    _, vjp = jax.vjp(lambda t: ref.maxpool_ref(t, window, stride), x)
    return (vjp(g)[0],)


maxpool.defvjp(_maxpool_fwd, _maxpool_bwd)
