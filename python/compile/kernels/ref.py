"""Pure-jnp correctness oracles for every Pallas kernel in this package.

Each ``*_ref`` function is the semantic ground truth: pytest (and the
hypothesis sweeps) assert that the Pallas implementations match these to
tight tolerances across shapes and dtypes.  The refs are also used as
the backward rule for kernels whose fwd is a Pallas kernel but whose
bwd we route through XLA (maxpool, LRN) — see the kernel modules.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain GEMM oracle: ``a @ b`` with f32 accumulation."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def bias_relu_ref(x: jax.Array, bias: jax.Array) -> jax.Array:
    """Fused bias+ReLU oracle; bias broadcasts over the leading axis."""
    return jnp.maximum(x + bias, 0.0).astype(x.dtype)


def conv2d_ref(
    x: jax.Array,
    w: jax.Array,
    stride: int = 1,
    padding: int = 0,
) -> jax.Array:
    """NCHW convolution oracle via XLA's conv (the "Caffe" analog).

    x: [N, Cin, H, W]; w: [Cout, Cin, Kh, Kw] -> [N, Cout, Ho, Wo].
    """
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def im2col_ref(x: jax.Array, kh: int, kw: int, stride: int, padding: int) -> jax.Array:
    """Patch extraction oracle: [N,C,H,W] -> [N*Ho*Wo, C*Kh*Kw].

    Column order is (C, Kh, Kw) — the filter matrix below must match.
    """
    n = x.shape[0]
    patches = lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # [N, C*Kh*Kw, Ho, Wo] with feature dim ordered (C, Kh, Kw)
    ckk = patches.shape[1]
    patches = jnp.moveaxis(patches, 1, -1)  # [N, Ho, Wo, C*Kh*Kw]
    return patches.reshape(n * patches.shape[1] * patches.shape[2], ckk)


def filter_matrix_ref(w: jax.Array) -> jax.Array:
    """Filter [Cout, Cin, Kh, Kw] -> GEMM operand [Cin*Kh*Kw, Cout]."""
    cout = w.shape[0]
    return w.reshape(cout, -1).T


def maxpool_ref(x: jax.Array, window: int, stride: int) -> jax.Array:
    """Overlapping max pooling oracle (NCHW, VALID padding)."""
    # NB: the init value must be a Python scalar so lax recognizes the
    # max-monoid and binds reduce_window_max_p (which has autodiff
    # rules); an array init falls back to generic reduce_window_p,
    # which does not support reverse-mode AD.
    init = -jnp.inf if jnp.issubdtype(x.dtype, jnp.floating) else jnp.iinfo(x.dtype).min
    return lax.reduce_window(
        x,
        init,
        lax.max,
        window_dimensions=(1, 1, window, window),
        window_strides=(1, 1, stride, stride),
        padding="VALID",
    )


def lrn_ref(
    x: jax.Array,
    depth_radius: int = 2,
    bias: float = 2.0,
    alpha: float = 1e-4,
    beta: float = 0.75,
) -> jax.Array:
    """AlexNet local response normalization across channels (NCHW).

    ``b_c = a_c / (k + alpha/n * sum_{c' in [c-r, c+r]} a_{c'}^2)^beta``
    with n = 2r+1, matching Krizhevsky et al. (2012) §3.3.
    """
    n = 2 * depth_radius + 1
    sq = (x * x).astype(jnp.float32)
    pad = [(0, 0), (depth_radius, depth_radius), (0, 0), (0, 0)]
    sq = jnp.pad(sq, pad)
    window_sum = lax.reduce_window(
        sq,
        0.0,  # Python scalar: keeps the add-monoid primitive (AD-capable)
        lax.add,
        window_dimensions=(1, n, 1, 1),
        window_strides=(1, 1, 1, 1),
        padding="VALID",
    )
    scale = (bias + (alpha / n) * window_sum) ** beta
    return (x / scale).astype(x.dtype)


def softmax_xent_ref(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy oracle. logits [B,K], labels s32 [B]."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def sgd_momentum_ref(w, v, g, lr, mu=0.9, wd=5e-4):
    """Paper's update rule: v <- mu*v - lr*(g + wd*w); w <- w + v."""
    v_new = mu * v - lr * (g + wd * w)
    return w + v_new, v_new


def avg_ref(a, b):
    """Fig-2 step-3 oracle: elementwise mean of two replicas."""
    return 0.5 * (a + b)


@partial(jax.jit, static_argnums=(2,))
def topk_correct_ref(logits: jax.Array, labels: jax.Array, k: int) -> jax.Array:
    """Count of examples whose label is within the top-k logits."""
    _, idx = lax.top_k(logits, k)
    hit = jnp.any(idx == labels[:, None], axis=-1)
    return jnp.sum(hit.astype(jnp.int32))
