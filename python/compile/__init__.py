"""Build-time-only compile package (L2 model + L1 kernels + AOT lowering).

Never imported at runtime: ``make artifacts`` runs ``python -m
compile.aot`` once, and the Rust binary consumes only the emitted
``artifacts/*.hlo.txt`` + ``artifacts/manifest.json``.
"""
