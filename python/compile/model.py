"""L2: AlexNet family — fwd pass, parameter specs, model configs.

Three sizes of the paper's architecture (5 conv / 3 pool / 2 LRN /
2 FC / softmax for the full net; scaled-down ``tiny`` and ``micro``
variants for the CPU testbed), all expressed over the L1 kernel surface
(``kernels.conv`` / ``kernels.maxpool`` / ``kernels.lrn``) so every
backend in Table 1 is a one-line switch.

Parameters are a flat *ordered* list of (name, shape, init) — the ABI
contract with the Rust side: ``params/store.rs`` materializes and feeds
them in exactly this order (see artifacts/manifest.json).
"""

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from .kernels import conv as kconv
from .kernels.lrn import lrn
from .kernels.maxpool import maxpool


@dataclass(frozen=True)
class ConvSpec:
    """One conv stage: conv(+bias+ReLU) [+ LRN] [+ overlapping maxpool]."""

    cout: int
    kernel: int
    stride: int
    pad: int
    lrn: bool = False
    pool: bool = False


@dataclass(frozen=True)
class ModelConfig:
    """Static architecture description; hashable so jit caches per config."""

    name: str
    image_hw: int
    in_channels: int
    num_classes: int
    convs: Tuple[ConvSpec, ...]
    fc_dims: Tuple[int, ...]
    dropout: float = 0.0
    pool_window: int = 3
    pool_stride: int = 2

    @property
    def input_shape(self) -> Tuple[int, int, int]:
        return (self.in_channels, self.image_hw, self.image_hw)


# --- The model zoo -------------------------------------------------------

# Krizhevsky et al. (2012) as described in the paper: 5 convs (3 pooled,
# 2 LRN'd), 2 FC + softmax.  227x227 input, 1000 classes.
ALEXNET = ModelConfig(
    name="alexnet",
    image_hw=227,
    in_channels=3,
    num_classes=1000,
    convs=(
        ConvSpec(96, 11, 4, 0, lrn=True, pool=True),
        ConvSpec(256, 5, 1, 2, lrn=True, pool=True),
        ConvSpec(384, 3, 1, 1),
        ConvSpec(384, 3, 1, 1),
        ConvSpec(256, 3, 1, 1, pool=True),
    ),
    fc_dims=(4096, 4096),
    dropout=0.5,
)

# CPU-testbed scale: same topology (5 convs, 2 LRN, 3 pools, 2 FC), a
# 64x64 synthetic-ImageNet input, 100 classes.  ~0.9 M parameters.
ALEXNET_TINY = ModelConfig(
    name="alexnet-tiny",
    image_hw=64,
    in_channels=3,
    num_classes=100,
    convs=(
        ConvSpec(32, 5, 2, 2, lrn=True, pool=True),
        ConvSpec(64, 3, 1, 1, lrn=True, pool=True),
        ConvSpec(96, 3, 1, 1),
        ConvSpec(96, 3, 1, 1),
        ConvSpec(64, 3, 1, 1, pool=True),
    ),
    fc_dims=(512, 256),
)

# Test/bench scale: 2 convs, one pool, one FC.  Seconds to lower.
ALEXNET_MICRO = ModelConfig(
    name="alexnet-micro",
    image_hw=32,
    in_channels=3,
    num_classes=10,
    convs=(
        ConvSpec(8, 5, 2, 2, lrn=True, pool=True),
        ConvSpec(16, 3, 1, 1),
    ),
    fc_dims=(64,),
)

MODELS = {m.name: m for m in (ALEXNET, ALEXNET_TINY, ALEXNET_MICRO)}


# --- Parameter specs -----------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    """Shape + init recipe for one tensor; mirrored into manifest.json."""

    name: str
    shape: Tuple[int, ...]
    init: str  # "normal" | "zeros" | "ones_scaled"
    std: float = 0.01
    bias_value: float = 0.0

    @property
    def size(self) -> int:
        out = 1
        for d in self.shape:
            out *= d
        return out


def _conv_out_hw(hw: int, spec: ConvSpec, cfg: ModelConfig) -> int:
    hw = (hw + 2 * spec.pad - spec.kernel) // spec.stride + 1
    if spec.pool:
        hw = (hw - cfg.pool_window) // cfg.pool_stride + 1
    return hw


def param_specs(cfg: ModelConfig) -> List[ParamSpec]:
    """Ordered parameter list. He-scaled normals for the scaled variants
    (they must actually learn on the synthetic corpus); AlexNet's paper
    init (N(0, 0.01^2), ones on conv2/4/5+fc biases) for the full net."""
    specs: List[ParamSpec] = []
    paper_init = cfg.name == "alexnet"
    cin = cfg.in_channels
    hw = cfg.image_hw
    for i, cs in enumerate(cfg.convs):
        fan_in = cin * cs.kernel * cs.kernel
        std = 0.01 if paper_init else (2.0 / fan_in) ** 0.5
        bias = 1.0 if (paper_init and i in (1, 3, 4)) else 0.0
        specs.append(
            ParamSpec(f"conv{i + 1}_w", (cs.cout, cin, cs.kernel, cs.kernel), "normal", std)
        )
        specs.append(ParamSpec(f"conv{i + 1}_b", (cs.cout,), "zeros", 0.0, bias))
        cin = cs.cout
        hw = _conv_out_hw(hw, cs, cfg)
    feat = cin * hw * hw
    dims = [feat, *cfg.fc_dims, cfg.num_classes]
    nfc = len(dims) - 1
    for j in range(nfc):
        std = 0.01 if paper_init else (2.0 / dims[j]) ** 0.5
        bias = 1.0 if paper_init and j < nfc - 1 else 0.0
        specs.append(ParamSpec(f"fc{j + 1}_w", (dims[j], dims[j + 1]), "normal", std))
        specs.append(ParamSpec(f"fc{j + 1}_b", (dims[j + 1],), "zeros", 0.0, bias))
    return specs


def init_params(cfg: ModelConfig, key: jax.Array) -> List[jax.Array]:
    """Python-side init (tests only; the runtime init lives in Rust)."""
    out = []
    for spec in param_specs(cfg):
        key, sub = jax.random.split(key)
        if spec.init == "normal":
            out.append(spec.std * jax.random.normal(sub, spec.shape, jnp.float32))
        else:
            out.append(jnp.full(spec.shape, spec.bias_value, jnp.float32))
    return out


# --- Forward pass ---------------------------------------------------------


def forward(
    cfg: ModelConfig,
    params: List[jax.Array],
    images: jax.Array,
    *,
    backend: str = "refconv",
    train: bool = False,
    dropout_key: Optional[jax.Array] = None,
) -> jax.Array:
    """AlexNet forward: images [B,C,H,W] f32 -> logits [B,num_classes].

    ``backend`` selects the conv/GEMM engine per Table 1; dropout is
    applied on the FC hidden layers only when ``train`` and
    ``cfg.dropout > 0`` (paper's full net).
    """
    it = iter(params)
    x = images
    for cs in cfg.convs:
        w, b = next(it), next(it)
        x = kconv.conv2d_bias_relu(
            x, w, b, stride=cs.stride, padding=cs.pad, backend=backend
        )
        if cs.lrn:
            x = lrn(x)
        if cs.pool:
            x = maxpool(x, cfg.pool_window, cfg.pool_stride)
    bsz = x.shape[0]
    x = x.reshape(bsz, -1)
    nfc = len(cfg.fc_dims)
    for j in range(nfc):
        w, b = next(it), next(it)
        x = kconv.linear_bias_relu(x, w, b, backend=backend)
        if train and cfg.dropout > 0.0:
            assert dropout_key is not None
            dropout_key, sub = jax.random.split(dropout_key)
            keep = jax.random.bernoulli(sub, 1.0 - cfg.dropout, x.shape)
            x = jnp.where(keep, x / (1.0 - cfg.dropout), 0.0)
    w, b = next(it), next(it)
    logits = kconv.linear(x, w, backend=backend) + b[None, :]
    return logits
