"""AOT lowering: JAX -> HLO *text* artifacts + manifest.json.

Run once at build time (``make artifacts``); the Rust runtime consumes
only the emitted files.  HLO text — NOT ``lowered.compile()`` or
serialized protos — is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
(the version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Manifest schema (consumed by rust/src/runtime/artifact.rs):

{
  "version": 1,
  "models": { name: { image_hw, in_channels, num_classes,
                      params: [ {name, shape, init, std, bias_value} ] } },
  "artifacts": [ { "name", "kind": "train"|"eval", "model", "backend",
                   "batch_size", "file",
                   "inputs":  [ {name, dtype, shape} ],
                   "outputs": [ {name, dtype, shape} ] } ]
}
"""

import argparse
import hashlib
import json
import os
import sys
import time
from typing import List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import MODELS, ModelConfig, param_specs
from .train_step import make_eval_step, make_train_step

# Default artifact set: micro x all backends for Table-1 calibration and
# the Rust test suite; tiny x refconv for the end-to-end driver (1-worker
# B=32 and 2-worker B=16, mirroring the paper's 256 vs 2x128 split);
# tiny x cudnn_r2 to run the Pallas path end-to-end.
DEFAULT_PLAN = [
    # (model, backend, train_batch, with_eval)
    ("alexnet-micro", "refconv", 8, True),
    ("alexnet-micro", "convnet", 8, False),
    ("alexnet-micro", "cudnn_r1", 8, False),
    ("alexnet-micro", "cudnn_r2", 8, True),
    ("alexnet-tiny", "refconv", 32, True),
    ("alexnet-tiny", "refconv", 16, False),
    ("alexnet-tiny", "cudnn_r2", 16, False),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_list(s) -> List[int]:
    return [int(d) for d in s]


def _io_entry(name, sds):
    return {
        "name": name,
        "dtype": jnp.dtype(sds.dtype).name,
        "shape": _shape_list(sds.shape),
    }


def lower_train(cfg: ModelConfig, backend: str, batch: int):
    specs = param_specs(cfg)
    n = len(specs)
    fn = make_train_step(cfg, backend, n)
    c, h = cfg.in_channels, cfg.image_hw
    args = [
        _spec((batch, c, h, h)),                 # images
        _spec((batch,), jnp.int32),              # labels
        _spec((), jnp.float32),                  # lr
        _spec((), jnp.int32),                    # seed
        *[_spec(s.shape) for s in specs],        # params
        *[_spec(s.shape) for s in specs],        # momenta
    ]
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    inputs = (
        [_io_entry("images", args[0]), _io_entry("labels", args[1]),
         _io_entry("lr", args[2]), _io_entry("seed", args[3])]
        + [_io_entry(s.name, _spec(s.shape)) for s in specs]
        + [_io_entry(s.name + ".m", _spec(s.shape)) for s in specs]
    )
    outputs = (
        [_io_entry("loss", _spec(())), _io_entry("correct1", _spec((), jnp.int32))]
        + [_io_entry(s.name, _spec(s.shape)) for s in specs]
        + [_io_entry(s.name + ".m", _spec(s.shape)) for s in specs]
    )
    return lowered, inputs, outputs


def lower_eval(cfg: ModelConfig, backend: str, batch: int):
    specs = param_specs(cfg)
    fn = make_eval_step(cfg, backend, len(specs))
    c, h = cfg.in_channels, cfg.image_hw
    args = [
        _spec((batch, c, h, h)),
        _spec((batch,), jnp.int32),
        *[_spec(s.shape) for s in specs],
    ]
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    inputs = [
        _io_entry("images", args[0]),
        _io_entry("labels", args[1]),
        *[_io_entry(s.name, _spec(s.shape)) for s in specs],
    ]
    outputs = [
        _io_entry("loss", _spec(())),
        _io_entry("correct1", _spec((), jnp.int32)),
        _io_entry("correct5", _spec((), jnp.int32)),
    ]
    return lowered, inputs, outputs


def model_entry(cfg: ModelConfig):
    return {
        "image_hw": cfg.image_hw,
        "in_channels": cfg.in_channels,
        "num_classes": cfg.num_classes,
        "params": [
            {
                "name": s.name,
                "shape": list(s.shape),
                "init": s.init,
                "std": s.std,
                "bias_value": s.bias_value,
            }
            for s in param_specs(cfg)
        ],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--plan",
        default=None,
        help="comma list of model:backend:batch[:eval] entries "
        "(default: the built-in plan)",
    )
    ns = ap.parse_args()

    plan = DEFAULT_PLAN
    if ns.plan:
        plan = []
        for entry in ns.plan.split(","):
            parts = entry.split(":")
            plan.append(
                (parts[0], parts[1], int(parts[2]), len(parts) > 3 and parts[3] == "eval")
            )

    os.makedirs(ns.out_dir, exist_ok=True)
    manifest = {"version": 1, "models": {}, "artifacts": []}

    for model_name, backend, batch, with_eval in plan:
        cfg = MODELS[model_name]
        manifest["models"].setdefault(model_name, model_entry(cfg))
        jobs = [("train", lower_train)]
        if with_eval:
            jobs.append(("eval", lower_eval))
        for kind, lower in jobs:
            t0 = time.time()
            lowered, inputs, outputs = lower(cfg, backend, batch)
            text = to_hlo_text(lowered)
            fname = f"{kind}_{model_name}_{backend}_b{batch}.hlo.txt"
            with open(os.path.join(ns.out_dir, fname), "w") as f:
                f.write(text)
            digest = hashlib.sha256(text.encode()).hexdigest()[:16]
            manifest["artifacts"].append(
                {
                    "name": f"{kind}_{model_name}_{backend}_b{batch}",
                    "kind": kind,
                    "model": model_name,
                    "backend": backend,
                    "batch_size": batch,
                    "file": fname,
                    "sha256_16": digest,
                    "inputs": inputs,
                    "outputs": outputs,
                }
            )
            print(
                f"[aot] {fname}: {len(text) / 1e6:.2f} MB HLO text "
                f"({time.time() - t0:.1f}s)",
                file=sys.stderr,
            )

    with open(os.path.join(ns.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest['artifacts'])} artifacts", file=sys.stderr)


if __name__ == "__main__":
    main()
