"""L2 model semantics: shapes, backend equivalence, SGD+momentum rule,
replica-averaging algebra, and the top-k workaround."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.model import ALEXNET, MODELS, forward, init_params, param_specs
from compile.train_step import (
    MOMENTUM,
    WEIGHT_DECAY,
    make_eval_step,
    make_train_step,
    _topk_correct,
)


def batch_for(cfg, b, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, cfg.in_channels, cfg.image_hw, cfg.image_hw)), jnp.float32)
    y = jnp.asarray(rng.integers(0, cfg.num_classes, b), jnp.int32)
    return x, y


def test_param_specs_order_and_counts():
    cfg = MODELS["alexnet-tiny"]
    specs = param_specs(cfg)
    names = [s.name for s in specs]
    assert names[0] == "conv1_w" and names[1] == "conv1_b"
    assert names[-2] == "fc3_w" and names[-1] == "fc3_b"
    # 5 convs + 3 fc = 8 layers, 2 tensors each.
    assert len(specs) == 16
    total = sum(s.size for s in specs)
    assert 500_000 < total < 1_000_000


def test_full_alexnet_has_60m_params():
    specs = param_specs(ALEXNET)
    total = sum(s.size for s in specs)
    assert 55_000_000 < total < 66_000_000, total


@pytest.mark.parametrize("model", ["alexnet-micro", "alexnet-tiny"])
def test_forward_shapes(model):
    cfg = MODELS[model]
    params = init_params(cfg, jax.random.key(0))
    x, _ = batch_for(cfg, 2)
    logits = forward(cfg, params, x, backend="refconv")
    assert logits.shape == (2, cfg.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_backends_agree_on_forward():
    cfg = MODELS["alexnet-micro"]
    params = init_params(cfg, jax.random.key(1))
    x, _ = batch_for(cfg, 2)
    base = forward(cfg, params, x, backend="refconv")
    for backend in ["convnet", "cudnn_r1", "cudnn_r2"]:
        other = forward(cfg, params, x, backend=backend)
        np.testing.assert_allclose(other, base, rtol=1e-3, atol=1e-3)


def test_train_step_applies_sgd_momentum_rule():
    cfg = MODELS["alexnet-micro"]
    specs = param_specs(cfg)
    step = make_train_step(cfg, "refconv", len(specs))
    params = init_params(cfg, jax.random.key(2))
    momenta = [jnp.zeros_like(p) for p in params]
    x, y = batch_for(cfg, 4)
    lr = jnp.float32(0.05)

    out = step(x, y, lr, jnp.int32(0), *params, *momenta)
    loss, correct1 = out[0], out[1]
    new_params = out[2 : 2 + len(specs)]
    new_momenta = out[2 + len(specs) :]

    # Recompute the update by hand from jax.grad.
    def scalar_loss(ps):
        logits = forward(cfg, list(ps), x, backend="refconv")
        return ref.softmax_xent_ref(logits, y)

    grads = jax.grad(scalar_loss)(params)
    for w, v, g, w2, v2 in zip(params, momenta, grads, new_params, new_momenta):
        v_want = MOMENTUM * v - lr * (g + WEIGHT_DECAY * w)
        np.testing.assert_allclose(v2, v_want, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(w2, w + v_want, rtol=1e-5, atol=1e-6)
    assert float(loss) > 0
    assert 0 <= int(correct1) <= 4


def test_identical_replicas_with_avg_match_large_batch_direction():
    """Fig-2 algebra: two replicas averaging after one step from the same
    init equal a single step on the averaged gradient — i.e. the 2x128
    scheme follows the same descent direction as b=256 (modulo
    weight-decay second-order terms, exact here because wd acts on the
    shared starting point)."""
    cfg = MODELS["alexnet-micro"]
    specs = param_specs(cfg)
    step = make_train_step(cfg, "refconv", len(specs))
    params = init_params(cfg, jax.random.key(3))
    momenta = [jnp.zeros_like(p) for p in params]
    xa, ya = batch_for(cfg, 4, seed=10)
    xb, yb = batch_for(cfg, 4, seed=11)
    lr = jnp.float32(0.01)

    out_a = step(xa, ya, lr, jnp.int32(0), *params, *momenta)
    out_b = step(xb, yb, lr, jnp.int32(0), *params, *momenta)
    avg = [0.5 * (a + b) for a, b in zip(out_a[2:], out_b[2:])]

    xab = jnp.concatenate([xa, xb])
    yab = jnp.concatenate([ya, yb])
    step_big = make_train_step(cfg, "refconv", len(specs))
    out_big = step_big(xab, yab, lr, jnp.int32(0), *params, *momenta)

    for got, want in zip(avg, out_big[2:]):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_eval_step_counts():
    cfg = MODELS["alexnet-micro"]
    specs = param_specs(cfg)
    ev = make_eval_step(cfg, "refconv", len(specs))
    params = init_params(cfg, jax.random.key(4))
    x, y = batch_for(cfg, 8)
    loss, c1, c5 = ev(x, y, *params)
    assert 0 <= int(c1) <= int(c5) <= 8
    assert float(loss) > 0


def test_topk_workaround_matches_lax_topk():
    rng = np.random.default_rng(9)
    logits = jnp.asarray(rng.standard_normal((64, 20)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 20, 64), jnp.int32)
    for k in (1, 5):
        ours = _topk_correct(logits, labels, k)
        real = ref.topk_correct_ref(logits, labels, k)
        assert int(ours) == int(real)


def test_training_reduces_loss_quickly():
    cfg = MODELS["alexnet-micro"]
    specs = param_specs(cfg)
    step = jax.jit(make_train_step(cfg, "refconv", len(specs)))
    params = init_params(cfg, jax.random.key(5))
    momenta = [jnp.zeros_like(p) for p in params]
    x, y = batch_for(cfg, 8)
    first = None
    for i in range(15):
        out = step(x, y, jnp.float32(0.05), jnp.int32(i), *params, *momenta)
        loss = float(out[0])
        if first is None:
            first = loss
        params = list(out[2 : 2 + len(specs)])
        momenta = list(out[2 + len(specs) :])
    assert loss < 0.5 * first, (first, loss)
