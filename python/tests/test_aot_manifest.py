"""AOT pipeline: HLO text validity and manifest consistency."""

import json
import os

import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile.aot import lower_eval, lower_train, model_entry, to_hlo_text
from compile.model import MODELS, param_specs


def test_lower_train_io_specs():
    cfg = MODELS["alexnet-micro"]
    lowered, inputs, outputs = lower_train(cfg, "refconv", 4)
    n = len(param_specs(cfg))
    assert len(inputs) == 4 + 2 * n
    assert len(outputs) == 2 + 2 * n
    assert inputs[0]["name"] == "images"
    assert inputs[0]["shape"] == [4, 3, 32, 32]
    assert inputs[1]["dtype"] == "int32"
    assert outputs[0] == {"name": "loss", "dtype": "float32", "shape": []}
    # HLO text parses back through the *current* xla_client (sanity; the
    # 0.5.1-compat constraints are exercised by the rust tests).
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    # keep_unused: every declared input must appear as a parameter.
    assert text.count("parameter(") >= 4 + 2 * n


def test_lower_train_has_no_topk_attribute():
    # xla_extension 0.5.1 rejects `largest=true`; guard the workaround.
    cfg = MODELS["alexnet-micro"]
    lowered, _, _ = lower_train(cfg, "refconv", 4)
    text = to_hlo_text(lowered)
    assert "largest=" not in text
    lowered, _, _ = lower_eval(cfg, "refconv", 4)
    assert "largest=" not in to_hlo_text(lowered)


def test_lower_eval_io_specs():
    cfg = MODELS["alexnet-micro"]
    _, inputs, outputs = lower_eval(cfg, "cudnn_r2", 8)
    assert len(inputs) == 2 + len(param_specs(cfg))
    assert [o["name"] for o in outputs] == ["loss", "correct1", "correct5"]


def test_model_entry_schema():
    e = model_entry(MODELS["alexnet-tiny"])
    assert e["image_hw"] == 64 and e["num_classes"] == 100
    assert all(
        set(p) == {"name", "shape", "init", "std", "bias_value"} for p in e["params"]
    )


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_built_manifest_consistent_with_files():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    assert len(manifest["artifacts"]) >= 5
    for art in manifest["artifacts"]:
        path = os.path.join(root, art["file"])
        assert os.path.exists(path), art["file"]
        model = manifest["models"][art["model"]]
        if art["kind"] == "train":
            assert len(art["inputs"]) == 4 + 2 * len(model["params"])
            assert len(art["outputs"]) == 2 + 2 * len(model["params"])
        # Parameter tensors in the ABI match the model's specs in order.
        abi_params = [i for i in art["inputs"][4 if art["kind"] == "train" else 2 :]]
        for spec, io in zip(model["params"], abi_params):
            assert io["name"].startswith(spec["name"])
            assert io["shape"] == spec["shape"]


def test_hlo_text_roundtrips_through_parser():
    # mlir -> XlaComputation -> text -> (new computation) is total.
    cfg = MODELS["alexnet-micro"]
    lowered, _, _ = lower_eval(cfg, "refconv", 2)
    text = to_hlo_text(lowered)
    assert text.strip().startswith("HloModule")
    assert "f32[2,3,32,32]" in text
