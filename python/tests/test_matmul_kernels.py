"""Pallas GEMM schedules vs the pure-jnp oracle — the core L1 signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_pallas as mp
from compile.kernels import ref

SCHEDULES = list(mp.SCHEDULES)


def rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (8, 8, 8),
        (37, 53, 29),       # nothing divides the block sizes
        (128, 128, 128),    # exactly one block
        (130, 70, 260),     # multi-block every dim
    ],
)
def test_matmul_matches_ref(schedule, m, k, n):
    rng = np.random.default_rng(0)
    a, b = rand(rng, m, k), rand(rng, k, n)
    got = mp.matmul(a, b, schedule)
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_matmul_grads_match_ref(schedule):
    rng = np.random.default_rng(1)
    a, b = rand(rng, 24, 17), rand(rng, 17, 9)

    def f(a_, b_):
        return jnp.sum(jnp.tanh(mp.matmul(a_, b_, schedule)))

    def fr(a_, b_):
        return jnp.sum(jnp.tanh(ref.matmul_ref(a_, b_)))

    ga, gb = jax.grad(f, argnums=(0, 1))(a, b)
    gra, grb = jax.grad(fr, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga, gra, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gb, grb, rtol=1e-4, atol=1e-5)


def test_fused_epilogue_matches_unfused():
    rng = np.random.default_rng(2)
    a, b = rand(rng, 40, 33), rand(rng, 33, 20)
    bias = rand(rng, 20)
    got = mp.matmul_bias_relu_fused(a, b, bias)
    want = ref.bias_relu_ref(ref.matmul_ref(a, b), bias)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_epilogue_grad():
    rng = np.random.default_rng(3)
    a, b = rand(rng, 12, 11), rand(rng, 11, 7)
    bias = rand(rng, 7)

    def f(a_, b_, bias_):
        return jnp.sum(mp.matmul_bias_relu_fused(a_, b_, bias_) ** 2)

    def fr(a_, b_, bias_):
        return jnp.sum(ref.bias_relu_ref(ref.matmul_ref(a_, b_), bias_) ** 2)

    g = jax.grad(f, argnums=(0, 1, 2))(a, b, bias)
    gr = jax.grad(fr, argnums=(0, 1, 2))(a, b, bias)
    for x, y in zip(g, gr):
        np.testing.assert_allclose(x, y, rtol=1e-4, atol=1e-5)


def test_bf16_inputs_accumulate_in_f32():
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.standard_normal((64, 256)), jnp.bfloat16)
    b = jnp.asarray(rng.standard_normal((256, 48)), jnp.bfloat16)
    got = mp.matmul(a, b, "cudnn_r1")
    assert got.dtype == jnp.bfloat16
    want = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    np.testing.assert_allclose(
        got.astype(jnp.float32), want, rtol=2e-2, atol=2e-1
    )


def test_shape_errors():
    a = jnp.zeros((4, 5))
    with pytest.raises(ValueError):
        mp.matmul(a, jnp.zeros((6, 3)), "cudnn_r1")
    with pytest.raises(ValueError):
        mp.matmul(jnp.zeros((4,)), jnp.zeros((4, 3)), "cudnn_r1")
    with pytest.raises(KeyError):
        mp.matmul(a, jnp.zeros((5, 3)), "warp9000")


def test_vmem_and_mxu_estimates():
    # Structural perf checks (interpret mode has no real TPU timing).
    for sched in SCHEDULES:
        vb = mp.vmem_block_bytes(512, 512, 512, sched)
        assert vb < 16 * 1024 * 1024, f"{sched} block spills VMEM: {vb}"
    # Aligned shapes achieve full utilization; misaligned ones less.
    assert mp.mxu_utilization_estimate(128, 128, 128, "cudnn_r1") == 1.0
    assert mp.mxu_utilization_estimate(129, 128, 128, "cudnn_r1") < 1.0
    # The naive schedule stages whole K panels: more VMEM than K-tiled.
    assert mp.vmem_block_bytes(512, 512, 2048, "convnet") > mp.vmem_block_bytes(
        512, 512, 2048, "cudnn_r1"
    )


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    schedule=st.sampled_from(SCHEDULES),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_matmul_shapes(m, k, n, schedule, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, k), rand(rng, k, n)
    got = mp.matmul(a, b, schedule)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4)
