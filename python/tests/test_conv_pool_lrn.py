"""Conv backends, maxpool and LRN kernels vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv as kconv
from compile.kernels import lrn as klrn
from compile.kernels import maxpool as kpool
from compile.kernels import ref

PALLAS_BACKENDS = ["convnet", "cudnn_r1", "cudnn_r2"]


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@pytest.mark.parametrize("backend", PALLAS_BACKENDS)
@pytest.mark.parametrize(
    "n,cin,h,cout,k,stride,pad",
    [
        (1, 1, 8, 1, 3, 1, 0),
        (2, 3, 13, 7, 3, 2, 1),
        (2, 5, 11, 4, 5, 2, 2),   # AlexNet-ish conv1
        (1, 4, 7, 6, 1, 1, 0),    # 1x1 conv
        (3, 2, 9, 5, 3, 3, 1),
    ],
)
def test_conv2d_matches_lax(backend, n, cin, h, cout, k, stride, pad):
    rng = np.random.default_rng(0)
    x = rand(rng, n, cin, h, h)
    w = rand(rng, cout, cin, k, k)
    got = kconv.conv2d(x, w, stride=stride, padding=pad, backend=backend)
    want = ref.conv2d_ref(x, w, stride, pad)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", PALLAS_BACKENDS + ["refconv"])
def test_conv_bias_relu(backend):
    rng = np.random.default_rng(1)
    x = rand(rng, 2, 3, 10, 10)
    w = rand(rng, 6, 3, 3, 3)
    b = rand(rng, 6)
    got = kconv.conv2d_bias_relu(x, w, b, stride=1, padding=1, backend=backend)
    want = jnp.maximum(ref.conv2d_ref(x, w, 1, 1) + b[None, :, None, None], 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert bool(jnp.all(got >= 0))


@pytest.mark.parametrize("backend", PALLAS_BACKENDS)
def test_conv_grads_match_ref(backend):
    rng = np.random.default_rng(2)
    x = rand(rng, 2, 3, 8, 8)
    w = rand(rng, 4, 3, 3, 3)

    def f(x_, w_):
        return jnp.sum(kconv.conv2d(x_, w_, stride=1, padding=1, backend=backend) ** 2)

    def fr(x_, w_):
        return jnp.sum(ref.conv2d_ref(x_, w_, 1, 1) ** 2)

    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    grx, grw = jax.grad(fr, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, grx, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(gw, grw, rtol=1e-3, atol=1e-4)


def test_linear_layers():
    rng = np.random.default_rng(3)
    x, w, b = rand(rng, 9, 15), rand(rng, 15, 8), rand(rng, 8)
    for backend in PALLAS_BACKENDS + ["refconv"]:
        got = kconv.linear_bias_relu(x, w, b, backend=backend)
        want = ref.bias_relu_ref(ref.matmul_ref(x, w), b)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window,stride,h", [(3, 2, 13), (2, 2, 8), (3, 3, 9), (3, 2, 32)])
def test_maxpool_matches_ref(window, stride, h):
    rng = np.random.default_rng(4)
    x = rand(rng, 2, 3, h, h)
    got = kpool.maxpool(x, window, stride)
    want = ref.maxpool_ref(x, window, stride)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want)


def test_maxpool_grad_matches_ref():
    rng = np.random.default_rng(5)
    x = rand(rng, 2, 2, 9, 9)
    g = jax.grad(lambda t: jnp.sum(kpool.maxpool(t, 3, 2) ** 2))(x)
    gr = jax.grad(lambda t: jnp.sum(ref.maxpool_ref(t, 3, 2) ** 2))(x)
    np.testing.assert_allclose(g, gr)


def test_lrn_matches_ref_and_grad():
    rng = np.random.default_rng(6)
    x = rand(rng, 2, 16, 6, 6)
    np.testing.assert_allclose(klrn.lrn(x), ref.lrn_ref(x), rtol=1e-5, atol=1e-6)
    g = jax.grad(lambda t: jnp.sum(klrn.lrn(t) ** 2))(x)
    gr = jax.grad(lambda t: jnp.sum(ref.lrn_ref(t) ** 2))(x)
    np.testing.assert_allclose(g, gr, rtol=1e-4, atol=1e-5)


def test_lrn_few_channels_edge():
    # Fewer channels than the window: padding path must still be exact.
    rng = np.random.default_rng(7)
    x = rand(rng, 1, 2, 4, 4)
    np.testing.assert_allclose(klrn.lrn(x), ref.lrn_ref(x), rtol=1e-5, atol=1e-6)


def test_lrn_suppresses_high_activity():
    # LRN divides by local channel energy: uniform big activations
    # shrink more than sparse ones (the "competition" AlexNet wanted).
    hot = jnp.ones((1, 8, 4, 4)) * 50.0
    cold = jnp.zeros((1, 8, 4, 4)).at[:, 0].set(50.0)
    out_hot = klrn.lrn(hot)[0, 0, 0, 0]
    out_cold = klrn.lrn(cold)[0, 0, 0, 0]
    assert out_cold > out_hot


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 3),
    cin=st.integers(1, 6),
    h=st.integers(5, 17),
    cout=st.integers(1, 8),
    k=st.sampled_from([1, 3, 5]),
    stride=st.integers(1, 3),
    backend=st.sampled_from(PALLAS_BACKENDS),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_conv_shapes(n, cin, h, cout, k, stride, backend, seed):
    pad = k // 2
    if h + 2 * pad < k:
        return
    rng = np.random.default_rng(seed)
    x = rand(rng, n, cin, h, h)
    w = rand(rng, cout, cin, k, k)
    got = kconv.conv2d(x, w, stride=stride, padding=pad, backend=backend)
    want = ref.conv2d_ref(x, w, stride, pad)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(4, 20),
    window=st.sampled_from([2, 3]),
    stride=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_maxpool_shapes(h, window, stride, seed):
    if h < window:
        return
    rng = np.random.default_rng(seed)
    x = rand(rng, 1, 2, h, h)
    got = kpool.maxpool(x, window, stride)
    want = ref.maxpool_ref(x, window, stride)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want)
